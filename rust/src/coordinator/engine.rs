//! `SpmmEngine` — the coordinator's core: register matrices, submit SpMM
//! requests, get adaptively-routed executions back from whichever
//! [`SpmmBackend`] the engine was built over.
//!
//! The engine owns everything backend-agnostic: handle management,
//! feature extraction, the Fig.-4 adaptive selector, dimension
//! validation, latency/metrics accounting. Execution itself — native CPU
//! kernels by default, PJRT artifacts behind the `pjrt` feature — is
//! entirely behind the trait.

use super::cache::PreparedCache;
use super::metrics::Metrics;
use crate::backend::{
    execute_sddmm_traced, execute_sddmm_variant_traced, execute_traced, execute_variant_traced,
    NativeBackend, PreparedOperand, SpmmBackend,
};
use crate::features::MatrixFeatures;
use crate::kernels::{registry, KernelKind, SparseOp, VariantEntry, WARP};
use crate::obs::{trace, AuditEntry};
use crate::selector::{AdaptiveSelector, Decision, OnlineConfig, OnlineSelector, SddmmSelector};
use crate::sparse::{CsrMatrix, DeltaOutcome, DenseMatrix, EdgeDelta};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Relative feature movement past which a delta batch triggers
/// re-selection ([`SpmmEngine::apply_delta`]): when `avg_row`, `cv_row`
/// or `nnz` moves by more than this fraction of its pre-batch value, the
/// kernel choices made from the old features are considered stale — the
/// static selectors re-decide into the audit log (grain `delta`) and the
/// online selector's matching cost buckets are reset.
pub const DRIFT_THRESHOLD: f64 = 0.25;

/// Handle to a registered matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MatrixHandle(usize);

struct Registered {
    features: MatrixFeatures,
    prepared: PreparedOperand,
    /// The source CSR this registration was prepared from — the base a
    /// delta batch ([`SpmmEngine::apply_delta`]) clones, mutates and
    /// re-prepares against. Kept per registration (not per handle): on a
    /// cached engine, content-identical handles share one copy.
    csr: CsrMatrix,
    /// Stable identity of this registration's prepared state: the content
    /// fingerprint on cached engines (shared by every handle that hit the
    /// same cache entry), a unique id otherwise. The serving layer routes
    /// and batches on this, so co-batchable traffic coalesces at the same
    /// grain the cache dedupes at.
    batch_key: u64,
}

/// The coordinator engine: adaptive selection + backend routing +
/// execution + metrics.
///
/// `metrics` is shared (`Arc`) so backends that produce sub-request
/// telemetry — the sharded backend records one entry per shard execution
/// — can write into the same instance the engine reports from.
pub struct SpmmEngine {
    backend: Box<dyn SpmmBackend>,
    /// Request-level kernel selector (the paper's Fig.-4 rules).
    pub selector: AdaptiveSelector,
    /// Request-level SDDMM kernel selector (the second-op rules —
    /// `crate::selector::sddmm`).
    pub sddmm_selector: SddmmSelector,
    /// Shared telemetry: request, shard, cache and admission counters.
    pub metrics: Arc<Metrics>,
    matrices: Mutex<HashMap<usize, Arc<Registered>>>,
    /// Prepared-matrix cache keyed by content fingerprint; `None` keeps
    /// the pre-serving behavior (every registration pays `prepare`).
    cache: Option<PreparedCache<Registered>>,
    /// Online-refined selector ([`SpmmEngine::serving_online`]): when
    /// present it overrides `selector` for request-level choices, and
    /// directly-executed (unsharded) requests report their latency back
    /// to it. Shared with the sharded backend so both grains learn from
    /// one cost table.
    online: Option<Arc<OnlineSelector>>,
    next_id: AtomicUsize,
}

/// Outcome of one SpMM request.
#[derive(Debug)]
pub struct SpmmResponse {
    /// The dense result `Y = A · X`.
    pub y: DenseMatrix,
    /// The request-level kernel choice that was executed (or hinted, on
    /// per-shard-adaptive backends).
    pub kernel: KernelKind,
    /// Executed unit: artifact name (pjrt) or `native/<kernel>` label.
    pub artifact: String,
    /// Wallclock of the backend execution.
    pub latency: std::time::Duration,
}

/// Outcome of one SDDMM request.
#[derive(Debug)]
pub struct SddmmResponse {
    /// One sampled value per non-zero of the registered matrix, in CSR
    /// stream order: `values[k] = A.values[k] * (U[r_k] · V[c_k])`.
    pub values: Vec<f32>,
    /// The request-level kernel choice that was executed (or hinted, on
    /// per-shard-adaptive backends).
    pub kernel: KernelKind,
    /// Executed unit, `native/sddmm/<kernel>`-style.
    pub artifact: String,
    /// Wallclock of the backend execution.
    pub latency: std::time::Duration,
}

impl SpmmEngine {
    /// Engine over the native CPU backend sized to available parallelism —
    /// the default deployment shape (no artifacts, no libxla).
    pub fn native() -> SpmmEngine {
        Self::with_backend(Box::new(NativeBackend::default()))
    }

    /// Engine over a `k`-way sharded native backend: matrices are split
    /// into nnz-balanced row shards at registration, and every request
    /// fans out with *per-shard* adaptive kernel selection (the engine's
    /// request-level choice is recorded as usual; each shard's own choice
    /// lands in the [`Metrics`] shard counters). `k = 1` behaves like
    /// [`SpmmEngine::native`] with sharding bookkeeping.
    pub fn sharded(k: usize) -> SpmmEngine {
        Self::sharded_with_selector(k, AdaptiveSelector::default())
    }

    /// [`SpmmEngine::sharded`] with explicit (e.g. calibrated) selector
    /// thresholds, installed at *both* grains: the engine's request-level
    /// selector and the backend's per-shard selector. Use this — not
    /// [`SpmmEngine::with_selector`] — to calibrate a sharded engine.
    pub fn sharded_with_selector(k: usize, selector: AdaptiveSelector) -> SpmmEngine {
        let metrics = Arc::new(Metrics::default());
        let backend = crate::shard::ShardedBackend::new(k)
            .adaptive(selector)
            .with_metrics(metrics.clone());
        let mut engine = Self::assemble(Box::new(backend), metrics);
        engine.selector = selector;
        engine
    }

    /// Engine over an explicit backend.
    ///
    /// A [`crate::shard::ShardedBackend`] boxed through here keeps its
    /// own private metrics instance — use
    /// [`SpmmEngine::with_sharded_backend`] instead so shard telemetry
    /// lands in the engine's metrics.
    pub fn with_backend(backend: Box<dyn SpmmBackend>) -> SpmmEngine {
        Self::assemble(backend, Arc::new(Metrics::default()))
    }

    /// Engine over a custom-composed sharded backend (e.g.
    /// `ShardedBackend::over(pjrt, k)`), rebinding the backend's shard
    /// counters to the engine's own [`Metrics`] so
    /// `engine.metrics.shard_*` observes the fan-out.
    pub fn with_sharded_backend(backend: crate::shard::ShardedBackend) -> SpmmEngine {
        let metrics = Arc::new(Metrics::default());
        let backend = backend.with_metrics(metrics.clone());
        Self::assemble(Box::new(backend), metrics)
    }

    /// The serving deployment shape: a size-routed backend (unsharded
    /// native below `shard_threshold_nnz` non-zeros, `shards`-way
    /// per-shard-adaptive above — shard telemetry lands in the engine's
    /// [`Metrics`]) behind a prepared-matrix cache of
    /// `cache_budget_bytes`. This is what `ge-spmm serve` and the
    /// multi-worker [`crate::coordinator::server::Server`] run on.
    pub fn serving(
        cache_budget_bytes: usize,
        shard_threshold_nnz: usize,
        shards: usize,
    ) -> SpmmEngine {
        Self::serving_with_selector(
            cache_budget_bytes,
            shard_threshold_nnz,
            shards,
            AdaptiveSelector::default(),
        )
    }

    /// [`SpmmEngine::serving`] with explicit selector thresholds —
    /// typically a loaded [`crate::selector::HardwareProfile`] — installed
    /// at both grains (request-level and per-shard), so a deployment
    /// boots with thresholds fitted to its own machine.
    pub fn serving_with_selector(
        cache_budget_bytes: usize,
        shard_threshold_nnz: usize,
        shards: usize,
        selector: AdaptiveSelector,
    ) -> SpmmEngine {
        Self::serving_with_selector_traced(
            cache_budget_bytes,
            shard_threshold_nnz,
            shards,
            selector,
            crate::obs::trace::DEFAULT_TRACE_CAPACITY,
        )
    }

    /// [`SpmmEngine::serving_with_selector`] with an explicit flight-
    /// recorder ring capacity (`serve --trace-capacity`): the shared
    /// [`Metrics`] hub keeps the last `trace_capacity` request traces.
    pub fn serving_with_selector_traced(
        cache_budget_bytes: usize,
        shard_threshold_nnz: usize,
        shards: usize,
        selector: AdaptiveSelector,
        trace_capacity: usize,
    ) -> SpmmEngine {
        let metrics = Arc::new(Metrics::with_trace_capacity(trace_capacity));
        let large = crate::shard::ShardedBackend::new(shards.max(1))
            .adaptive(selector)
            .with_metrics(metrics.clone());
        let backend = crate::backend::RoutedBackend::over(
            Box::new(NativeBackend::default()),
            Box::new(large),
            shard_threshold_nnz,
        );
        let mut engine = Self::assemble(Box::new(backend), metrics);
        engine.selector = selector;
        engine.with_prepared_cache(cache_budget_bytes)
    }

    /// The serving shape with **online selector refinement**: one shared
    /// [`OnlineSelector`] (seeded from `base` — paper defaults or a
    /// loaded hardware profile) drives request-level choices on the
    /// unsharded route and per-shard choices on the sharded route, every
    /// execution's latency feeds its cost EWMAs, and its periodic refits
    /// shift later choices. See `DESIGN.md` §Measured calibration.
    ///
    /// On the sharded route the request-level choice (exploration
    /// included) is only the usual hint — each shard re-selects and
    /// reports its own execution, so request-grain exploration slots
    /// spent on large matrices buy no extra evidence. Size the admission
    /// threshold (or the exploration cadence) accordingly if the traffic
    /// mix is mostly large matrices.
    pub fn serving_online(
        cache_budget_bytes: usize,
        shard_threshold_nnz: usize,
        shards: usize,
        base: AdaptiveSelector,
        config: OnlineConfig,
    ) -> SpmmEngine {
        Self::serving_online_traced(
            cache_budget_bytes,
            shard_threshold_nnz,
            shards,
            base,
            config,
            crate::obs::trace::DEFAULT_TRACE_CAPACITY,
        )
    }

    /// [`SpmmEngine::serving_online`] with an explicit flight-recorder
    /// ring capacity (`serve --trace-capacity`).
    pub fn serving_online_traced(
        cache_budget_bytes: usize,
        shard_threshold_nnz: usize,
        shards: usize,
        base: AdaptiveSelector,
        config: OnlineConfig,
        trace_capacity: usize,
    ) -> SpmmEngine {
        let metrics = Arc::new(Metrics::with_trace_capacity(trace_capacity));
        let online = Arc::new(OnlineSelector::new(base, metrics.clone(), config));
        // RoutedBackend::online records shard telemetry into the
        // selector's metrics — the same instance as the engine's, so
        // request-, shard- and EWMA-level observations all land together.
        let backend =
            crate::backend::RoutedBackend::online(shard_threshold_nnz, shards, online.clone());
        let mut engine = Self::assemble(Box::new(backend), metrics);
        engine.selector = base;
        engine.online = Some(online);
        engine.with_prepared_cache(cache_budget_bytes)
    }

    /// The shared online selector, when this engine was built with
    /// [`SpmmEngine::serving_online`].
    pub fn online(&self) -> Option<Arc<OnlineSelector>> {
        self.online.clone()
    }

    /// Enable the prepared-matrix cache: registrations of
    /// content-identical matrices (same [`CsrMatrix::fingerprint`]) reuse
    /// the backend-prepared state instead of paying `prepare` again. The
    /// budget is denominated in source-CSR heap bytes
    /// ([`CsrMatrix::heap_bytes`]); least-recently-registered matrices
    /// are evicted when it overflows. Hits, misses and evictions are
    /// observable through [`Metrics`].
    pub fn with_prepared_cache(mut self, budget_bytes: usize) -> Self {
        self.cache = Some(PreparedCache::new(budget_bytes));
        self
    }

    fn assemble(backend: Box<dyn SpmmBackend>, metrics: Arc<Metrics>) -> SpmmEngine {
        SpmmEngine {
            backend,
            selector: AdaptiveSelector::default(),
            sddmm_selector: SddmmSelector::default(),
            metrics,
            matrices: Mutex::new(HashMap::new()),
            cache: None,
            online: None,
            next_id: AtomicUsize::new(0),
        }
    }

    /// Engine over the PJRT artifact backend (see `make artifacts`).
    #[cfg(feature = "pjrt")]
    pub fn new(artifact_dir: &std::path::Path) -> Result<SpmmEngine> {
        Ok(Self::with_backend(Box::new(
            crate::backend::PjrtBackend::new(artifact_dir)?,
        )))
    }

    /// With a custom (e.g. calibrated) selector.
    ///
    /// This sets the *request-level* selector only. A sharded backend's
    /// per-shard selector is fixed at construction — build calibrated
    /// sharded engines with [`SpmmEngine::sharded_with_selector`] instead.
    pub fn with_selector(mut self, selector: AdaptiveSelector) -> Self {
        self.selector = selector;
        self
    }

    /// With custom (e.g. [`crate::selector::sddmm::calibrate_sddmm`]-fit)
    /// request-level SDDMM thresholds. As with
    /// [`SpmmEngine::with_selector`], a sharded backend's per-shard SDDMM
    /// selector is fixed at construction
    /// (`ShardedBackend::with_sddmm_selector`).
    pub fn with_sddmm_selector(mut self, selector: SddmmSelector) -> Self {
        self.sddmm_selector = selector;
        self
    }

    /// Label of the backend this engine executes on.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The backend itself (diagnostics, downcasting).
    pub fn backend(&self) -> &dyn SpmmBackend {
        self.backend.as_ref()
    }

    /// Register a sparse matrix; features are extracted and the backend's
    /// prepared operand is built once here, off the request path. With a
    /// prepared-matrix cache ([`SpmmEngine::with_prepared_cache`]),
    /// registering content-identical matrices — same
    /// [`CsrMatrix::fingerprint`] — shares one prepared state across
    /// handles and skips `prepare` entirely on a hit.
    pub fn register(&self, csr: CsrMatrix) -> Result<MatrixHandle> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let registered = match &self.cache {
            Some(cache) => {
                let fingerprint = csr.fingerprint();
                match cache.get(fingerprint) {
                    Some(hit) => {
                        self.metrics.record_cache_hit();
                        hit
                    }
                    None => {
                        self.metrics.record_cache_miss();
                        let bytes = csr.heap_bytes();
                        let fresh = Arc::new(Registered {
                            features: MatrixFeatures::of(&csr),
                            prepared: self.backend.prepare(&csr)?,
                            batch_key: fingerprint,
                            csr,
                        });
                        let evicted = cache.insert(fingerprint, fresh.clone(), bytes);
                        self.metrics.record_cache_evictions(evicted);
                        fresh
                    }
                }
            }
            None => Arc::new(Registered {
                features: MatrixFeatures::of(&csr),
                prepared: self.backend.prepare(&csr)?,
                batch_key: id as u64,
                csr,
            }),
        };
        self.matrices.lock().unwrap().insert(id, registered);
        Ok(MatrixHandle(id))
    }

    /// Stable identity of the prepared state a handle resolves to: on a
    /// cached engine, handles registered from content-identical matrices
    /// share one key (the fingerprint); otherwise each registration has
    /// its own. The serving layer routes and batches on this, so
    /// co-batchable traffic from distinct handles still coalesces.
    pub fn batch_key(&self, h: MatrixHandle) -> Result<u64> {
        Ok(self.get(h)?.batch_key)
    }

    /// Apply a dynamic-graph mutation batch to a registered matrix
    /// without tearing the registration down.
    ///
    /// The batch is applied to a clone of the registration's source CSR
    /// (requests in flight keep executing against the pre-batch snapshot
    /// — they hold the old `Arc` — and never observe a half-patched
    /// state), then the prepared state is refreshed the cheapest way the
    /// backend supports: [`SpmmBackend::prepare_delta`] patches in place
    /// for value-only batches, anything structural falls back to a full
    /// `prepare`. The epoch bump moves the content fingerprint, so on a
    /// cached engine the stale cache entry is evicted and the new state
    /// inserted under the new key — a later registration of either the
    /// pre-batch content or an epoch-0 rebuild of the post-batch content
    /// is a miss, never a stale hit.
    ///
    /// If the post-batch features moved past [`DRIFT_THRESHOLD`] the
    /// selector decisions made from the old features are stale: the
    /// current thresholds re-decide both ops into the audit log (grain
    /// `delta`, selectors `drift` / `drift-sddmm`) and the online
    /// selector — when present — forgets the cost buckets the old and
    /// new features map to.
    ///
    /// Concurrent `apply_delta` calls on one handle are last-writer-wins
    /// (each clones the base it saw); serialize batches per handle for a
    /// deterministic mutation sequence. A batch that touches nothing
    /// (empty, or deletes of absent edges only) leaves the registration
    /// — epoch, batch key, cache entry — untouched.
    pub fn apply_delta(&self, h: MatrixHandle, delta: &EdgeDelta) -> Result<DeltaOutcome> {
        let reg = self.get(h)?;
        let mut req = trace::request(
            "delta",
            &format!("delta#{}", h.0),
            self.metrics.recorder(),
        );
        req.set_attr("matrix", h.0);
        let mut csr = reg.csr.clone();
        let report = delta.apply(&mut csr);
        req.set_attr("inserted", report.inserted);
        req.set_attr("deleted", report.deleted);
        req.set_attr("updated", report.updated);
        req.set_attr("structural", report.structural);
        if report.touched() == 0 {
            req.set_attr("patched", true);
            req.set_attr("drift", false);
            return Ok(DeltaOutcome {
                report,
                patched: true,
                epoch: csr.epoch,
                drift: false,
                reselected: false,
            });
        }
        let features = MatrixFeatures::of(&csr);
        let drift = Self::drifted(&reg.features, &features);
        if drift {
            self.audit_drift(h, &features);
            if let Some(online) = &self.online {
                online.reset_for_drift(&reg.features, &features);
            }
        }
        req.set_attr("drift", drift);
        let (prepared, patched) = match self
            .backend
            .prepare_delta(&reg.prepared, &csr, report.structural)
        {
            Some(result) => match result {
                Ok(prepared) => (prepared, true),
                Err(e) => {
                    self.metrics.record_error();
                    req.set_attr("error", &e);
                    return Err(e);
                }
            },
            None => match self.backend.prepare(&csr) {
                Ok(prepared) => (prepared, false),
                Err(e) => {
                    self.metrics.record_error();
                    req.set_attr("error", &e);
                    return Err(e);
                }
            },
        };
        req.set_attr("patched", patched);
        let epoch = csr.epoch;
        let fingerprint = csr.fingerprint();
        let bytes = csr.heap_bytes();
        let batch_key = if self.cache.is_some() {
            fingerprint
        } else {
            reg.batch_key
        };
        let fresh = Arc::new(Registered {
            features,
            prepared,
            batch_key,
            csr,
        });
        {
            let mut map = self.matrices.lock().unwrap();
            match map.get_mut(&h.0) {
                Some(slot) => *slot = fresh.clone(),
                // lost a race with unregister: don't resurrect the handle
                None => return Err(anyhow!("matrix handle {:?} was unregistered mid-delta", h)),
            }
        }
        if let Some(cache) = &self.cache {
            cache.remove(reg.batch_key);
            let evicted = cache.insert(fingerprint, fresh, bytes);
            self.metrics.record_cache_evictions(evicted);
        }
        Ok(DeltaOutcome {
            report,
            patched,
            epoch,
            drift,
            reselected: drift,
        })
    }

    /// Relative feature movement check behind [`DRIFT_THRESHOLD`].
    fn drifted(old: &MatrixFeatures, new: &MatrixFeatures) -> bool {
        let rel = |new: f64, old: f64| (new - old).abs() / old.abs().max(1e-9);
        rel(new.avg_row, old.avg_row) > DRIFT_THRESHOLD
            || rel(new.cv_row, old.cv_row) > DRIFT_THRESHOLD
            || rel(new.nnz as f64, old.nnz as f64) > DRIFT_THRESHOLD
    }

    /// Re-run both ops' selector decisions against post-drift features
    /// and push them into the audit log at grain `delta`, so `explain`
    /// shows *why* the next request's choice may differ from the last.
    /// Uses the online selector's refined thresholds when present (they
    /// survive the drift reset — still the best known rule), the static
    /// ones otherwise. Decided at reference widths (`n = 32`, `d =`
    /// [`WARP`]): the entries record the feature-side consequence of the
    /// mutation; per-request widths still decide at dispatch time.
    fn audit_drift(&self, h: MatrixHandle, features: &MatrixFeatures) {
        const REF_N: usize = 32;
        let spmm = self
            .online
            .as_ref()
            .map(|o| o.current())
            .unwrap_or(self.selector)
            .decide(features, REF_N);
        let sddmm = self
            .online
            .as_ref()
            .map(|o| o.current_sddmm())
            .unwrap_or(self.sddmm_selector)
            .decide(features, WARP);
        for (op, selector, n, decision) in [
            (SparseOp::Spmm, "drift", REF_N, spmm),
            (SparseOp::Sddmm, "drift-sddmm", WARP, sddmm),
        ] {
            self.metrics.audit().push(AuditEntry {
                seq: 0,
                op,
                grain: "delta",
                shard: None,
                selector,
                matrix: Some(h.0),
                features: *features,
                n,
                thresholds: decision.thresholds,
                rule: decision.rule,
                kernel: decision.kernel,
                variant: None,
                explored: false,
                realized_cost: None,
            });
        }
    }

    /// Drop a handle's registration, releasing the engine's reference to
    /// its prepared state *and* evicting the matching prepared-cache
    /// entry — unregister means "this content is done", so the cache must
    /// not keep billing its budget for state nothing routes to (a
    /// re-registration of the same content is a deliberate miss). A
    /// content-identical sibling handle keeps its own `Arc` and keeps
    /// serving; only the shared cache entry is gone. Returns whether the
    /// handle was registered. Handles are never recycled; long-running
    /// serving deployments should unregister handles they no longer route
    /// to, or the handle map grows with every registration.
    pub fn unregister(&self, h: MatrixHandle) -> bool {
        // bind before matching: drops the map guard before touching the
        // cache, so the two locks are never held together
        let removed = self.matrices.lock().unwrap().remove(&h.0);
        match removed {
            Some(reg) => {
                if let Some(cache) = &self.cache {
                    cache.remove(reg.batch_key);
                }
                true
            }
            None => false,
        }
    }

    /// `(entries, resident bytes)` of the prepared-matrix cache, or
    /// `None` if the engine was built without one.
    pub fn cache_usage(&self) -> Option<(usize, usize)> {
        self.cache.as_ref().map(|c| (c.len(), c.bytes()))
    }

    /// Features of a registered matrix.
    pub fn features(&self, h: MatrixHandle) -> Result<MatrixFeatures> {
        Ok(self.get(h)?.features)
    }

    fn get(&self, h: MatrixHandle) -> Result<Arc<Registered>> {
        self.matrices
            .lock()
            .unwrap()
            .get(&h.0)
            .cloned()
            .ok_or_else(|| anyhow!("unknown matrix handle {:?}", h))
    }

    /// Dense widths the backend routes natively (ascending), or `None` if
    /// it accepts any width (no fixed-shape artifact library).
    pub fn available_n(&self) -> Option<Vec<usize>> {
        self.backend.available_n()
    }

    /// Record one request-grain selector decision into the audit log and
    /// return the chosen kernel.
    #[allow(clippy::too_many_arguments)]
    fn audit_request(
        &self,
        op: SparseOp,
        selector: &'static str,
        h: MatrixHandle,
        features: MatrixFeatures,
        n: usize,
        decision: Decision,
        variant: Option<&'static str>,
        explored: bool,
    ) -> KernelKind {
        let kernel = decision.kernel;
        self.metrics.audit().push(AuditEntry {
            seq: 0,
            op,
            grain: "request",
            shard: None,
            selector,
            matrix: Some(h.0),
            features,
            n,
            thresholds: decision.thresholds,
            rule: decision.rule,
            kernel,
            variant,
            explored,
            realized_cost: None,
        });
        kernel
    }

    /// The audit log's explain report restricted to one handle's
    /// request-grain decisions: for each retained decision, the features
    /// the selector saw, the thresholds it consulted (enough to replay
    /// the rule), the kernel it chose (plus the generated variant, when
    /// one was dispatched), and the realized normalized cost once the
    /// online path observed it. Footed with the variant-space shape the
    /// selector chooses from, so a report is interpretable on its own.
    pub fn explain(&self, h: MatrixHandle) -> String {
        let mut report = self.metrics.audit().explain(Some(h.0));
        let reg = registry();
        if !report.ends_with('\n') && !report.is_empty() {
            report.push('\n');
        }
        report.push_str(&format!(
            "variant space: {} generated ({} spmm, {} sddmm) across {} families\n",
            reg.len(),
            reg.op_variants(SparseOp::Spmm).len(),
            reg.op_variants(SparseOp::Sddmm).len(),
            KernelKind::ALL.len()
        ));
        if let Some(online) = &self.online {
            report.push_str(&online.summary());
            report.push('\n');
        }
        report
    }

    /// Execute `Y = A · X` with adaptive kernel selection (the online
    /// selector's choice — exploration included — when this engine was
    /// built with [`SpmmEngine::serving_online`]).
    pub fn spmm(&self, h: MatrixHandle, x: &DenseMatrix) -> Result<SpmmResponse> {
        let reg = self.get(h)?;
        match &self.online {
            Some(online) => {
                let (decision, entry, explored) = online.decide_variant(&reg.features, x.cols);
                let kernel = self.audit_request(
                    SparseOp::Spmm,
                    "online",
                    h,
                    reg.features,
                    x.cols,
                    decision,
                    Some(entry.label),
                    explored,
                );
                self.spmm_dispatch(h, x, kernel, Some(entry))
            }
            None => {
                let decision = self.selector.decide(&reg.features, x.cols);
                let kernel = self.audit_request(
                    SparseOp::Spmm,
                    "adaptive",
                    h,
                    reg.features,
                    x.cols,
                    decision,
                    None,
                    false,
                );
                self.spmm_dispatch(h, x, kernel, None)
            }
        }
    }

    /// Execute with an explicit kernel choice (oracle / ablation paths).
    ///
    /// Adaptive sharded backends ([`SpmmEngine::sharded`]) treat `kernel`
    /// as a hint: each shard re-selects from its own features, and the
    /// actual per-shard choices are observable via the [`Metrics`] shard
    /// counters.
    pub fn spmm_with(
        &self,
        h: MatrixHandle,
        x: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<SpmmResponse> {
        self.spmm_dispatch(h, x, kernel, None)
    }

    /// Shared execution tail of [`SpmmEngine::spmm`] /
    /// [`SpmmEngine::spmm_with`]: with a resolved variant the backend runs
    /// that exact generated kernel (and metrics index its registry slot);
    /// without one the family-grain path is unchanged.
    fn spmm_dispatch(
        &self,
        h: MatrixHandle,
        x: &DenseMatrix,
        kernel: KernelKind,
        entry: Option<&'static VariantEntry>,
    ) -> Result<SpmmResponse> {
        let reg = self.get(h)?;
        // One "dispatch" span per request: inside an admitted serving
        // trace this nests under the installed context; on direct engine
        // calls the guard owns a fresh trace and commits it to the flight
        // recorder when dropped, so both paths are explorable.
        let mut req = trace::request(
            "dispatch",
            &format!("spmm#{}", h.0),
            self.metrics.recorder(),
        );
        req.set_attr("op", SparseOp::Spmm.label());
        req.set_attr("kernel", kernel.label());
        if let Some(e) = entry {
            req.set_attr("variant", e.label);
        }
        req.set_attr("n", x.cols);
        req.set_attr("matrix", h.0);
        if let Err(e) = reg.prepared.check_operand(x) {
            self.metrics.record_error();
            req.set_attr("error", &e);
            return Err(e);
        }
        let start = Instant::now();
        let result = match entry {
            Some(e) => execute_variant_traced(self.backend.as_ref(), &reg.prepared, x, e),
            None => execute_traced(self.backend.as_ref(), &reg.prepared, x, kernel),
        };
        let exec = match result {
            Ok(exec) => exec,
            Err(e) => {
                self.metrics.record_error();
                req.set_attr("error", &e);
                return Err(e);
            }
        };
        req.set_attr("artifact", &exec.artifact);
        let latency = start.elapsed();
        match entry {
            Some(e) => {
                self.metrics.record_request_variant(e.id, latency);
            }
            None => self.metrics.record(kernel, latency),
        }
        // Roofline accounting for directly-executed native requests: the
        // analytic workload of the exact variant that ran (the family
        // hint's canonical variant when no generated entry was resolved).
        // Sharded fan-outs account per shard inside the sharded backend,
        // so gating on the `native/` artifact label prevents double
        // counting.
        if exec.artifact.starts_with("native/") {
            let ran = entry.unwrap_or_else(|| registry().canonical(SparseOp::Spmm, kernel));
            let est = crate::obs::workload::estimate(
                &ran.variant,
                reg.features.rows,
                reg.features.nnz,
                x.cols,
            );
            self.metrics.record_workload(ran.id, &est, latency);
        }
        // Close the online loop for directly-executed requests. Sharded
        // executions already observed per shard (with per-shard features
        // and actual per-shard choices), so only the unsharded route —
        // recognizable by its `native/<kernel>` artifact label — reports
        // here; a whole-request observation of a fan-out would attribute
        // gather overhead to whichever kernel the hint named.
        if let Some(online) = &self.online {
            if exec.artifact.starts_with("native/") {
                match entry {
                    Some(e) => online.observe_variant(&reg.features, x.cols, e, latency),
                    None => online.observe(&reg.features, x.cols, kernel, latency),
                }
            }
        }
        Ok(SpmmResponse {
            y: exec.y,
            kernel,
            artifact: exec.artifact,
            latency,
        })
    }

    /// Execute `S = sample(A, U·Vᵀ)` with adaptive kernel selection (the
    /// online selector's choice — exploration included — on engines built
    /// with [`SpmmEngine::serving_online`]). The registered matrix's
    /// prepared state is shared with SpMM traffic: op-mixed workloads on
    /// one graph pay `prepare` once.
    pub fn sddmm(
        &self,
        h: MatrixHandle,
        u: &DenseMatrix,
        v: &DenseMatrix,
    ) -> Result<SddmmResponse> {
        let reg = self.get(h)?;
        let d = u.cols;
        match &self.online {
            Some(online) => {
                let (decision, entry, explored) = online.decide_sddmm_variant(&reg.features, d);
                let kernel = self.audit_request(
                    SparseOp::Sddmm,
                    "online-sddmm",
                    h,
                    reg.features,
                    d,
                    decision,
                    Some(entry.label),
                    explored,
                );
                self.sddmm_dispatch(h, u, v, kernel, Some(entry))
            }
            None => {
                let decision = self.sddmm_selector.decide(&reg.features, d);
                let kernel = self.audit_request(
                    SparseOp::Sddmm,
                    "sddmm",
                    h,
                    reg.features,
                    d,
                    decision,
                    None,
                    false,
                );
                self.sddmm_dispatch(h, u, v, kernel, None)
            }
        }
    }

    /// Execute SDDMM with an explicit kernel choice (oracle / ablation
    /// paths). As with [`SpmmEngine::spmm_with`], per-shard-adaptive
    /// backends treat `kernel` as a hint — the actual per-shard choices
    /// land in the [`Metrics`] SDDMM shard counters.
    pub fn sddmm_with(
        &self,
        h: MatrixHandle,
        u: &DenseMatrix,
        v: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<SddmmResponse> {
        self.sddmm_dispatch(h, u, v, kernel, None)
    }

    /// Shared execution tail of [`SpmmEngine::sddmm`] /
    /// [`SpmmEngine::sddmm_with`], mirroring `spmm_dispatch`.
    fn sddmm_dispatch(
        &self,
        h: MatrixHandle,
        u: &DenseMatrix,
        v: &DenseMatrix,
        kernel: KernelKind,
        entry: Option<&'static VariantEntry>,
    ) -> Result<SddmmResponse> {
        let reg = self.get(h)?;
        let mut req = trace::request(
            "dispatch",
            &format!("sddmm#{}", h.0),
            self.metrics.recorder(),
        );
        req.set_attr("op", SparseOp::Sddmm.label());
        req.set_attr("kernel", kernel.label());
        if let Some(e) = entry {
            req.set_attr("variant", e.label);
        }
        req.set_attr("d", u.cols);
        req.set_attr("matrix", h.0);
        if let Err(e) = reg.prepared.check_sddmm_operands(u, v) {
            self.metrics.record_error();
            req.set_attr("error", &e);
            return Err(e);
        }
        let start = Instant::now();
        let result = match entry {
            Some(e) => {
                execute_sddmm_variant_traced(self.backend.as_ref(), &reg.prepared, u, v, e)
            }
            None => execute_sddmm_traced(self.backend.as_ref(), &reg.prepared, u, v, kernel),
        };
        let exec = match result {
            Ok(exec) => exec,
            Err(e) => {
                self.metrics.record_error();
                req.set_attr("error", &e);
                return Err(e);
            }
        };
        req.set_attr("artifact", &exec.artifact);
        let latency = start.elapsed();
        match entry {
            Some(e) => {
                self.metrics.record_request_variant(e.id, latency);
            }
            None => self.metrics.record_sddmm(kernel, latency),
        }
        // Roofline accounting for directly-executed native SDDMM,
        // mirroring `spmm_dispatch` (sharded fan-outs account per shard).
        if exec.artifact.starts_with("native/sddmm/") {
            let ran = entry.unwrap_or_else(|| registry().canonical(SparseOp::Sddmm, kernel));
            let est = crate::obs::workload::estimate(
                &ran.variant,
                reg.features.rows,
                reg.features.nnz,
                u.cols,
            );
            self.metrics.record_workload(ran.id, &est, latency);
        }
        // Close the online loop for directly-executed requests, mirroring
        // `spmm_dispatch`: sharded fan-outs already observed per shard.
        if let Some(online) = &self.online {
            if exec.artifact.starts_with("native/sddmm/") {
                match entry {
                    Some(e) => online.observe_variant(&reg.features, u.cols, e, latency),
                    None => online.observe_sddmm(&reg.features, u.cols, kernel, latency),
                }
            }
        }
        Ok(SddmmResponse {
            values: exec.values,
            kernel,
            artifact: exec.artifact,
            latency,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dense::spmm_reference;
    use crate::sparse::CooMatrix;
    use crate::util::prng::Xoshiro256;
    use crate::util::proptest::assert_close;

    fn matrix(seed: u64) -> CsrMatrix {
        let mut rng = Xoshiro256::seeded(seed);
        CsrMatrix::from_coo(&CooMatrix::random_uniform(80, 60, 0.1, &mut rng))
    }

    #[test]
    fn native_engine_round_trip_all_kernels() {
        let engine = SpmmEngine::native();
        assert_eq!(engine.backend_name(), "native");
        assert_eq!(engine.available_n(), None);
        let a = matrix(301);
        let h = engine.register(a.clone()).unwrap();
        let mut rng = Xoshiro256::seeded(302);
        let x = DenseMatrix::random(60, 7, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(80, 7);
        spmm_reference(&a, &x, &mut want);
        for kind in KernelKind::ALL {
            let resp = engine.spmm_with(h, &x, kind).unwrap();
            assert_eq!(resp.kernel, kind);
            assert!(resp.artifact.contains(kind.label()));
            assert_close(&resp.y.data, &want.data, 1e-5, 1e-5).unwrap();
        }
        assert_eq!(engine.metrics.requests(), 4);
        assert_eq!(engine.metrics.errors(), 0);
    }

    #[test]
    fn adaptive_selection_executes_and_records() {
        let engine = SpmmEngine::native();
        let a = matrix(303);
        let h = engine.register(a.clone()).unwrap();
        let mut rng = Xoshiro256::seeded(304);
        let x = DenseMatrix::random(60, 32, 1.0, &mut rng);
        let resp = engine.spmm(h, &x).unwrap();
        let expect = engine.selector.select(&engine.features(h).unwrap(), x.cols);
        assert_eq!(resp.kernel, expect);
        let mut want = DenseMatrix::zeros(80, 32);
        spmm_reference(&a, &x, &mut want);
        assert_close(&resp.y.data, &want.data, 1e-5, 1e-5).unwrap();
        assert_eq!(engine.metrics.kernel_counts().iter().sum::<u64>(), 1);
    }

    #[test]
    fn adaptive_requests_leave_an_audit_trail_and_a_trace() {
        let engine = SpmmEngine::native();
        let h = engine.register(matrix(330)).unwrap();
        let mut rng = Xoshiro256::seeded(331);
        let x = DenseMatrix::random(60, 32, 1.0, &mut rng);
        let resp = engine.spmm(h, &x).unwrap();
        // audit: the retained request-grain decision reproduces the choice
        let entries = engine.metrics.audit().for_matrix(0);
        assert_eq!(entries.len(), 1);
        let e = &entries[0];
        assert_eq!(e.kernel, resp.kernel);
        assert_eq!(e.grain, "request");
        assert_eq!(e.selector, "adaptive");
        assert_eq!(e.n, 32);
        let report = engine.explain(h);
        assert!(report.contains(resp.kernel.label()), "{report}");
        // trace: the direct call committed one trace to the recorder,
        // with the kernel span nested under dispatch
        let traces = engine.metrics.recorder().traces();
        assert_eq!(traces.len(), 1);
        let t = &traces[0];
        assert_eq!(t.label, "spmm#0");
        let dispatch = t.span("dispatch").unwrap();
        assert_eq!(dispatch.attr("op"), Some("spmm"));
        assert_eq!(dispatch.attr("artifact"), Some(resp.artifact.as_str()));
        let kernel = t.span("kernel").unwrap();
        assert_eq!(kernel.parent, dispatch.id);
        assert!(kernel.duration_ns() > 0, "kernel span has a real duration");
    }

    #[test]
    fn sharded_engine_matches_native_and_counts_shards() {
        let a = matrix(307);
        let mut rng = Xoshiro256::seeded(308);
        let x = DenseMatrix::random(60, 16, 1.0, &mut rng);
        let native = SpmmEngine::native();
        let sharded = SpmmEngine::sharded(3);
        assert_eq!(sharded.backend_name(), "sharded");
        let hn = native.register(a.clone()).unwrap();
        let hs = sharded.register(a).unwrap();
        let want = native.spmm(hn, &x).unwrap();
        let got = sharded.spmm(hs, &x).unwrap();
        assert_close(&got.y.data, &want.y.data, 1e-5, 1e-5).unwrap();
        assert!(got.artifact.starts_with("sharded(k="), "{}", got.artifact);
        // one request, one shard execution per shard
        assert_eq!(sharded.metrics.requests(), 1);
        assert_eq!(
            sharded.metrics.shard_executions(),
            sharded.metrics.shard_kernel_counts().iter().sum::<u64>()
        );
        assert!(sharded.metrics.shard_executions() >= 2);
        assert!(sharded.metrics.summary().contains("shards["), "shared Arc");
        // features are those of the whole matrix, not a shard
        assert_eq!(
            sharded.features(hs).unwrap().rows,
            native.features(hn).unwrap().rows
        );
    }

    #[test]
    fn sharded_with_selector_installs_thresholds_at_both_grains() {
        let custom = AdaptiveSelector {
            n_threshold: 2,
            t_avg: 5.0,
            t_cv: 0.5,
            ..AdaptiveSelector::default()
        };
        let engine = SpmmEngine::sharded_with_selector(2, custom);
        assert_eq!(engine.selector, custom);
        // the request-level choice follows the custom thresholds
        let h = engine.register(matrix(310)).unwrap();
        let mut rng = Xoshiro256::seeded(311);
        let x = DenseMatrix::random(60, 3, 1.0, &mut rng);
        let resp = engine.spmm(h, &x).unwrap();
        assert_eq!(
            resp.kernel,
            custom.select(&engine.features(h).unwrap(), 3)
        );
    }

    #[test]
    fn sharded_engine_diverges_kernels_across_regimes() {
        // Two-regime fixture: at N=1 the long-row head shard wants PR-RS,
        // the short-row tail PR-WB.
        let mut rng = Xoshiro256::seeded(309);
        let engine = SpmmEngine::sharded(2);
        let h = engine
            .register(crate::shard::features::two_regime_matrix())
            .unwrap();
        let x = DenseMatrix::random(2048, 1, 1.0, &mut rng);
        engine.spmm(h, &x).unwrap();
        assert_eq!(engine.metrics.shard_kernel_counts(), [0, 0, 1, 1]);
    }

    #[test]
    fn sddmm_round_trip_with_per_op_counters() {
        use crate::kernels::dense::sddmm_reference;
        let engine = SpmmEngine::native();
        let a = matrix(318); // 80x60
        let h = engine.register(a.clone()).unwrap();
        let mut rng = Xoshiro256::seeded(319);
        let d = 8;
        let u = DenseMatrix::random(80, d, 1.0, &mut rng);
        let v = DenseMatrix::random(60, d, 1.0, &mut rng);
        let mut want = vec![0f32; a.nnz()];
        sddmm_reference(&a, &u, &v, &mut want);
        let resp = engine.sddmm(h, &u, &v).unwrap();
        let expect = engine
            .sddmm_selector
            .select(&engine.features(h).unwrap(), d);
        assert_eq!(resp.kernel, expect);
        assert!(resp.artifact.starts_with("native/sddmm/"), "{}", resp.artifact);
        assert_eq!(resp.values, want, "bit-for-bit vs the dense reference");
        // op-tagged counters: the SDDMM request is not an SpMM request
        assert_eq!(engine.metrics.requests(), 0);
        assert_eq!(engine.metrics.sddmm_requests(), 1);
        assert_eq!(engine.metrics.sddmm_kernel_counts().iter().sum::<u64>(), 1);
        // explicit-kernel path covers all four designs
        for kind in KernelKind::ALL {
            let resp = engine.sddmm_with(h, &u, &v, kind).unwrap();
            assert_eq!(resp.values, want, "{kind:?}");
        }
        assert_eq!(engine.metrics.sddmm_requests(), 5);
        // shape mismatch is rejected and counted
        assert!(engine.sddmm(h, &DenseMatrix::zeros(80, 3), &v).is_err());
        assert_eq!(engine.metrics.errors(), 1);
    }

    #[test]
    fn sddmm_routes_and_fans_out_on_the_serving_shape() {
        use crate::kernels::dense::sddmm_reference;
        let a = {
            let mut rng = Xoshiro256::seeded(320);
            CsrMatrix::from_coo(&CooMatrix::random_uniform(300, 80, 0.1, &mut rng))
        };
        // threshold 1 => the matrix routes through the sharded side
        let engine = SpmmEngine::serving(16 << 20, 1, 2);
        let h = engine.register(a.clone()).unwrap();
        let mut rng = Xoshiro256::seeded(321);
        let d = 8;
        let u = DenseMatrix::random(300, d, 1.0, &mut rng);
        let v = DenseMatrix::random(80, d, 1.0, &mut rng);
        let mut want = vec![0f32; a.nnz()];
        sddmm_reference(&a, &u, &v, &mut want);
        let resp = engine.sddmm(h, &u, &v).unwrap();
        assert!(resp.artifact.starts_with("sharded(k="), "{}", resp.artifact);
        assert_eq!(resp.values, want);
        assert!(engine.metrics.sddmm_shard_executions() >= 2, "fan-out recorded");
        assert_eq!(engine.metrics.shard_executions(), 0, "SpMM shard counters untouched");
    }

    #[test]
    fn dimension_mismatch_is_rejected_and_counted() {
        let engine = SpmmEngine::native();
        let h = engine.register(matrix(305)).unwrap();
        let x = DenseMatrix::zeros(59, 4); // should be 60 rows
        assert!(engine.spmm(h, &x).is_err());
        assert_eq!(engine.metrics.errors(), 1);
        assert_eq!(engine.metrics.requests(), 0);
    }

    #[test]
    fn prepared_cache_shares_state_across_handles() {
        let engine = SpmmEngine::native().with_prepared_cache(64 << 20);
        assert_eq!(engine.cache_usage(), Some((0, 0)));
        let a = matrix(312);
        let bytes = a.heap_bytes();
        let h1 = engine.register(a.clone()).unwrap();
        let h2 = engine.register(a.clone()).unwrap();
        assert_ne!(h1, h2, "handles stay distinct across cache hits");
        assert_eq!(engine.metrics.cache_misses(), 1);
        assert_eq!(engine.metrics.cache_hits(), 1);
        assert_eq!(engine.cache_usage(), Some((1, bytes)));
        // both handles execute against the shared prepared state
        let mut rng = Xoshiro256::seeded(313);
        let x = DenseMatrix::random(60, 4, 1.0, &mut rng);
        let y1 = engine.spmm(h1, &x).unwrap().y;
        let y2 = engine.spmm(h2, &x).unwrap().y;
        assert_eq!(y1.data, y2.data);
    }

    #[test]
    fn serving_engine_routes_by_size_and_counts_cache() {
        let small = matrix(314);
        let large = {
            let mut rng = Xoshiro256::seeded(315);
            CsrMatrix::from_coo(&CooMatrix::random_uniform(300, 60, 0.2, &mut rng))
        };
        assert!(small.nnz() < large.nnz());
        let engine = SpmmEngine::serving(64 << 20, small.nnz() + 1, 2);
        assert_eq!(engine.backend_name(), "routed");
        let hs = engine.register(small.clone()).unwrap();
        let hl = engine.register(large.clone()).unwrap();
        let mut rng = Xoshiro256::seeded(316);
        let x = DenseMatrix::random(60, 8, 1.0, &mut rng);
        let resp_small = engine.spmm(hs, &x).unwrap();
        assert!(
            resp_small.artifact.starts_with("native/"),
            "{}",
            resp_small.artifact
        );
        assert_eq!(engine.metrics.shard_executions(), 0);
        let resp_large = engine.spmm(hl, &x).unwrap();
        assert!(
            resp_large.artifact.starts_with("sharded(k="),
            "{}",
            resp_large.artifact
        );
        assert!(engine.metrics.shard_executions() >= 2);
        // results agree with the reference on both routes
        for (m, resp) in [(&small, &resp_small), (&large, &resp_large)] {
            let mut want = DenseMatrix::zeros(m.rows, 8);
            spmm_reference(m, &x, &mut want);
            assert_close(&resp.y.data, &want.data, 1e-4, 1e-4).unwrap();
        }
        assert_eq!(engine.metrics.cache_misses(), 2);
    }

    #[test]
    fn serving_with_selector_installs_thresholds_at_both_grains() {
        let custom = AdaptiveSelector {
            n_threshold: 4,
            t_avg: 48.0,
            t_cv: 0.25,
            ..AdaptiveSelector::default()
        };
        // threshold 1 => everything routes through the sharded side
        let engine = SpmmEngine::serving_with_selector(16 << 20, 1, 2, custom);
        assert_eq!(engine.selector, custom);
        assert!(engine.online().is_none());
        let a = matrix(401);
        let f = MatrixFeatures::of(&a);
        assert!(f.cv_row > 0.25 && f.cv_row < 1.5, "cv {}", f.cv_row);
        let h = engine.register(a).unwrap();
        let mut rng = Xoshiro256::seeded(402);
        let x = DenseMatrix::random(60, 8, 1.0, &mut rng);
        let resp = engine.spmm(h, &x).unwrap();
        // default T_cv = 1.5 would pick SR-RS here; the custom 0.25
        // flips both the request-level choice and every shard's
        assert_eq!(resp.kernel, KernelKind::SrWb);
        let counts = engine.metrics.shard_kernel_counts();
        assert!(counts[1] >= 2, "shards use the custom thresholds: {counts:?}");
        assert_eq!(counts[0] + counts[2] + counts[3], 0, "{counts:?}");
    }

    #[test]
    fn serving_online_engine_learns_on_the_unsharded_route() {
        use std::time::Duration;
        let engine = SpmmEngine::serving_online(
            16 << 20,
            usize::MAX, // everything stays on the unsharded route
            2,
            AdaptiveSelector::default(),
            OnlineConfig {
                explore_every: 0,
                refit_every: 0,
                min_observations: 1,
            },
        );
        let online = engine.online().expect("online engine exposes its selector");
        let a = matrix(403);
        let f = MatrixFeatures::of(&a);
        assert!(f.cv_row > 0.3 && f.cv_row < 1.5, "cv {}", f.cv_row);
        let h = engine.register(a).unwrap();
        let mut rng = Xoshiro256::seeded(404);
        let x = DenseMatrix::random(60, 8, 1.0, &mut rng);
        let resp = engine.spmm(h, &x).unwrap();
        assert!(resp.artifact.starts_with("native/"), "{}", resp.artifact);
        assert_eq!(resp.kernel, KernelKind::SrRs, "default rule choice");
        assert_eq!(online.observations(), 1, "direct execution observed");
        // teach it SR-WB is cheaper on this bucket, refit, and the
        // request-level choice shifts — visible in the kernel counters
        for _ in 0..4 {
            online.observe(&f, 8, KernelKind::SrRs, Duration::from_millis(4));
            online.observe(&f, 8, KernelKind::SrWb, Duration::from_micros(40));
        }
        assert!(online.refit());
        let resp2 = engine.spmm(h, &x).unwrap();
        assert_eq!(resp2.kernel, KernelKind::SrWb, "{}", online.summary());
        assert_eq!(engine.metrics.kernel_counts()[1], 1);
    }

    #[test]
    fn unknown_handle_is_rejected() {
        let engine = SpmmEngine::native();
        let other = SpmmEngine::native();
        let h = other.register(matrix(306)).unwrap();
        assert!(engine.spmm(h, &DenseMatrix::zeros(60, 1)).is_err());
        assert!(engine.features(h).is_err());
    }

    #[test]
    fn unregister_evicts_the_prepared_cache_entry() {
        let engine = SpmmEngine::native().with_prepared_cache(64 << 20);
        let a = matrix(317);
        let bytes = a.heap_bytes();
        let h = engine.register(a.clone()).unwrap();
        assert_eq!(engine.cache_usage(), Some((1, bytes)));
        assert!(engine.unregister(h));
        assert!(!engine.unregister(h), "second unregister is a no-op");
        assert!(engine.spmm(h, &DenseMatrix::zeros(60, 1)).is_err());
        // unregister means "this content is done": the cache entry is
        // gone and its bytes stop counting against the budget
        assert_eq!(engine.cache_usage(), Some((0, 0)));
        // re-registering the same content is a deliberate miss
        let h2 = engine.register(a).unwrap();
        assert_ne!(h, h2);
        assert_eq!(engine.metrics.cache_hits(), 0);
        assert_eq!(engine.metrics.cache_misses(), 2);
        assert_eq!(engine.cache_usage(), Some((1, bytes)));
    }

    /// A batch of `extra` insertions at coordinates the matrix does not
    /// populate — net growth, guaranteed structural.
    fn growth_delta(a: &CsrMatrix, extra: usize) -> EdgeDelta {
        let mut delta = EdgeDelta::new();
        let mut added = 0;
        'rows: for r in 0..a.rows {
            let (cols, _) = a.row(r);
            for c in 0..a.cols as u32 {
                if cols.binary_search(&c).is_err() {
                    delta.insert(r, c as usize, 1.0);
                    added += 1;
                    if added == extra {
                        break 'rows;
                    }
                }
            }
        }
        assert_eq!(added, extra, "matrix too dense for the requested growth");
        delta
    }

    #[test]
    fn apply_delta_patches_value_only_batches_in_place() {
        let engine = SpmmEngine::native();
        let a = matrix(501);
        let h = engine.register(a.clone()).unwrap();
        let r = (0..a.rows).find(|&r| a.row_nnz(r) > 0).unwrap();
        let c = a.row(r).0[0] as usize;
        let mut delta = EdgeDelta::new();
        delta.insert(r, c, 9.5);
        let out = engine.apply_delta(h, &delta).unwrap();
        assert!(out.patched, "value-only batch patches the prepared state");
        assert!(!out.report.structural);
        assert_eq!(out.report.updated, 1);
        assert_eq!((out.report.inserted, out.report.deleted), (0, 0));
        assert_eq!(out.epoch, 1);
        assert!(!out.drift && !out.reselected);
        // the patched engine answers for the mutated content, bit-for-bit
        // against a from-scratch registration
        let mut m = a;
        delta.apply(&mut m);
        let fresh = SpmmEngine::native();
        let hf = fresh.register(m).unwrap();
        let mut rng = Xoshiro256::seeded(511);
        let x = DenseMatrix::random(60, 8, 1.0, &mut rng);
        for kind in KernelKind::ALL {
            assert_eq!(
                engine.spmm_with(h, &x, kind).unwrap().y.data,
                fresh.spmm_with(hf, &x, kind).unwrap().y.data,
                "{kind:?}"
            );
        }
        // the delta trace landed in the flight recorder
        let traces = engine.metrics.recorder().traces();
        let t = traces.iter().find(|t| t.label == "delta#0").unwrap();
        let span = t.span("delta").unwrap();
        assert_eq!(span.attr("patched"), Some("true"));
        assert_eq!(span.attr("updated"), Some("1"));
    }

    #[test]
    fn apply_delta_re_prepares_on_structural_batches() {
        let engine = SpmmEngine::native();
        let a = matrix(505);
        let h = engine.register(a.clone()).unwrap();
        let mut delta = growth_delta(&a, 1);
        let r = (0..a.rows).find(|&r| a.row_nnz(r) > 0).unwrap();
        delta.delete(r, a.row(r).0[0] as usize);
        let out = engine.apply_delta(h, &delta).unwrap();
        assert!(!out.patched, "structural batch falls back to full prepare");
        assert!(out.report.structural);
        assert_eq!((out.report.inserted, out.report.deleted), (1, 1));
        assert_eq!(out.epoch, 1);
        let mut m = a;
        delta.apply(&mut m);
        assert_eq!(engine.features(h).unwrap().nnz, m.nnz(), "features refreshed");
        let fresh = SpmmEngine::native();
        let hf = fresh.register(m).unwrap();
        let mut rng = Xoshiro256::seeded(512);
        let x = DenseMatrix::random(60, 4, 1.0, &mut rng);
        for kind in KernelKind::ALL {
            assert_eq!(
                engine.spmm_with(h, &x, kind).unwrap().y.data,
                fresh.spmm_with(hf, &x, kind).unwrap().y.data,
                "{kind:?}"
            );
        }
    }

    #[test]
    fn absent_only_deltas_leave_the_registration_alone() {
        let engine = SpmmEngine::native().with_prepared_cache(64 << 20);
        let a = matrix(506);
        let h = engine.register(a.clone()).unwrap();
        let key = engine.batch_key(h).unwrap();
        let r0 = (0..a.rows).find(|&r| a.row_nnz(r) < a.cols).unwrap();
        let c0 = (0..a.cols as u32)
            .find(|c| a.row(r0).0.binary_search(c).is_err())
            .unwrap();
        let mut delta = EdgeDelta::new();
        delta.delete(r0, c0 as usize);
        let out = engine.apply_delta(h, &delta).unwrap();
        assert_eq!(out.report.touched(), 0);
        assert_eq!(out.epoch, 0, "no-op batches do not bump the epoch");
        assert!(out.patched && !out.drift && !out.reselected);
        assert_eq!(engine.batch_key(h).unwrap(), key, "cache key unchanged");
        assert_eq!(engine.cache_usage().unwrap().0, 1);
    }

    #[test]
    fn apply_delta_rotates_the_cache_key_and_evicts_the_stale_entry() {
        let engine = SpmmEngine::native().with_prepared_cache(64 << 20);
        let a = matrix(502);
        let h = engine.register(a.clone()).unwrap();
        let key0 = engine.batch_key(h).unwrap();
        let r = (0..a.rows).find(|&r| a.row_nnz(r) > 0).unwrap();
        let mut delta = EdgeDelta::new();
        delta.insert(r, a.row(r).0[0] as usize, -3.0);
        engine.apply_delta(h, &delta).unwrap();
        let key1 = engine.batch_key(h).unwrap();
        assert_ne!(key0, key1, "batch key follows the (content, epoch) fingerprint");
        assert_eq!(
            engine.cache_usage().unwrap().0,
            1,
            "stale entry evicted, fresh one resident"
        );
        // the pre-mutation content no longer hits...
        engine.register(a.clone()).unwrap();
        assert_eq!(engine.metrics.cache_hits(), 0);
        assert_eq!(engine.metrics.cache_misses(), 2);
        // ...and neither does an epoch-0 rebuild of the post-mutation
        // content: the fingerprint is (content, epoch)-aware
        let mut m = a;
        delta.apply(&mut m);
        assert_eq!(m.epoch, 1);
        let rebuilt = CsrMatrix::from_parts(
            m.rows,
            m.cols,
            m.indptr.clone(),
            m.indices.clone(),
            m.values.clone(),
        );
        engine.register(rebuilt).unwrap();
        assert_eq!(engine.metrics.cache_hits(), 0);
        assert_eq!(engine.metrics.cache_misses(), 3);
    }

    #[test]
    fn drift_triggers_reselection_and_a_delta_grain_audit_trail() {
        let engine = SpmmEngine::native();
        let a = matrix(503);
        let h = engine.register(a.clone()).unwrap();
        let f0 = engine.features(h).unwrap();
        let delta = growth_delta(&a, a.nnz() / 3 + 2); // nnz grows >25%
        let out = engine.apply_delta(h, &delta).unwrap();
        assert!(out.drift, "nnz moved past DRIFT_THRESHOLD");
        assert!(out.reselected);
        let f1 = engine.features(h).unwrap();
        assert!(f1.nnz as f64 > f0.nnz as f64 * (1.0 + DRIFT_THRESHOLD));
        let entries = engine.metrics.audit().for_matrix(0);
        let delta_entries: Vec<_> = entries.iter().filter(|e| e.grain == "delta").collect();
        assert_eq!(delta_entries.len(), 2, "one SpMM + one SDDMM reselection");
        assert!(delta_entries
            .iter()
            .any(|e| e.selector == "drift" && e.op == SparseOp::Spmm));
        assert!(delta_entries
            .iter()
            .any(|e| e.selector == "drift-sddmm" && e.op == SparseOp::Sddmm));
        for e in &delta_entries {
            assert_eq!(e.features.nnz, f1.nnz, "audited against post-batch features");
            assert!(!e.explored);
        }
        let traces = engine.metrics.recorder().traces();
        let t = traces.iter().find(|t| t.label == "delta#0").unwrap();
        assert_eq!(t.span("delta").unwrap().attr("drift"), Some("true"));
    }

    #[test]
    fn drift_resets_the_online_cost_buckets() {
        let engine = SpmmEngine::serving_online(
            16 << 20,
            usize::MAX, // everything stays on the unsharded route
            2,
            AdaptiveSelector::default(),
            OnlineConfig {
                explore_every: 0,
                refit_every: 0,
                min_observations: 1,
            },
        );
        let online = engine.online().unwrap();
        let a = matrix(504);
        let h = engine.register(a.clone()).unwrap();
        let f0 = engine.features(h).unwrap();
        let mut rng = Xoshiro256::seeded(513);
        let x = DenseMatrix::random(60, 8, 1.0, &mut rng);
        let resp = engine.spmm(h, &x).unwrap();
        let bucket = crate::selector::online::feature_bucket(&f0, 8);
        assert!(
            engine.metrics.cost(bucket, resp.kernel).is_some(),
            "direct execution seeded the cost table"
        );
        let out = engine.apply_delta(h, &growth_delta(&a, a.nnz() / 3 + 2)).unwrap();
        assert!(out.drift && out.reselected);
        assert!(
            engine.metrics.cost(bucket, resp.kernel).is_none(),
            "drift cleared the stale bucket"
        );
        assert_eq!(online.observations(), 1, "counters are history, not live state");
    }
}
