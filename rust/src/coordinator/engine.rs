//! `SpmmEngine` — the coordinator's core: register matrices, submit SpMM
//! requests, get adaptively-routed PJRT executions back.

use super::metrics::Metrics;
use super::pack;
use crate::features::MatrixFeatures;
use crate::kernels::KernelKind;
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::Engine;
use crate::selector::AdaptiveSelector;
use crate::sparse::{CsrMatrix, DenseMatrix, EllMatrix, SegmentedMatrix};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Handle to a registered matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct MatrixHandle(usize);

struct Registered {
    csr: CsrMatrix,
    features: MatrixFeatures,
    ell_width: usize,
    num_segments: usize,
    /// packed + literal-converted operand cache keyed by artifact name
    packed: Mutex<HashMap<String, Arc<Vec<xla::Literal>>>>,
}

/// The coordinator engine: adaptive selection + artifact routing +
/// execution + metrics.
pub struct SpmmEngine {
    runtime: Engine,
    pub selector: AdaptiveSelector,
    pub metrics: Metrics,
    matrices: Mutex<HashMap<usize, Arc<Registered>>>,
    next_id: AtomicUsize,
}

/// Outcome of one SpMM request.
#[derive(Debug)]
pub struct SpmmResponse {
    pub y: DenseMatrix,
    pub kernel: KernelKind,
    pub artifact: String,
    pub latency: std::time::Duration,
}

impl SpmmEngine {
    /// Build over an artifact directory (see `make artifacts`).
    pub fn new(artifact_dir: &std::path::Path) -> Result<SpmmEngine> {
        Ok(SpmmEngine {
            runtime: Engine::new(artifact_dir)?,
            selector: AdaptiveSelector::default(),
            metrics: Metrics::default(),
            matrices: Mutex::new(HashMap::new()),
            next_id: AtomicUsize::new(0),
        })
    }

    /// With a custom (e.g. calibrated) selector.
    pub fn with_selector(mut self, selector: AdaptiveSelector) -> Self {
        self.selector = selector;
        self
    }

    /// Register a sparse matrix; features and format metadata are
    /// extracted once here, off the request path.
    pub fn register(&self, csr: CsrMatrix) -> MatrixHandle {
        let features = MatrixFeatures::of(&csr);
        let ell_width = EllMatrix::from_csr(&csr, 1, 1).width;
        let num_segments = SegmentedMatrix::from_csr(&csr, 32).num_segments;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.matrices.lock().unwrap().insert(
            id,
            Arc::new(Registered {
                csr,
                features,
                ell_width,
                num_segments,
                packed: Mutex::new(HashMap::new()),
            }),
        );
        MatrixHandle(id)
    }

    /// Features of a registered matrix.
    pub fn features(&self, h: MatrixHandle) -> Result<MatrixFeatures> {
        Ok(self.get(h)?.features)
    }

    fn get(&self, h: MatrixHandle) -> Result<Arc<Registered>> {
        self.matrices
            .lock()
            .unwrap()
            .get(&h.0)
            .cloned()
            .ok_or_else(|| anyhow!("unknown matrix handle {:?}", h))
    }

    /// The artifact dense widths available for routing, ascending.
    pub fn available_n(&self) -> Vec<usize> {
        let mut ns: Vec<usize> = self
            .runtime
            .manifest
            .artifacts
            .iter()
            .filter_map(|a| a.n)
            .collect();
        ns.sort_unstable();
        ns.dedup();
        ns
    }

    /// Smallest artifact width ≥ n.
    fn route_n(&self, n: usize) -> Result<usize> {
        self.available_n()
            .into_iter()
            .find(|&a| a >= n)
            .ok_or_else(|| anyhow!("no artifact bucket for n={n}"))
    }

    /// Execute `Y = A · X` with adaptive kernel selection.
    pub fn spmm(&self, h: MatrixHandle, x: &DenseMatrix) -> Result<SpmmResponse> {
        let reg = self.get(h)?;
        let kernel = self.selector.select(&reg.features, x.cols);
        self.spmm_with(h, x, kernel)
    }

    /// Execute with an explicit kernel choice (oracle / ablation paths).
    pub fn spmm_with(
        &self,
        h: MatrixHandle,
        x: &DenseMatrix,
        kernel: KernelKind,
    ) -> Result<SpmmResponse> {
        let reg = self.get(h)?;
        if x.rows != reg.csr.cols {
            self.metrics.record_error();
            return Err(anyhow!(
                "inner dimension mismatch: A is {}x{}, X is {}x{}",
                reg.csr.rows,
                reg.csr.cols,
                x.rows,
                x.cols
            ));
        }
        let n_bucket = self.route_n(x.cols.max(1))?;
        let spec = self
            .runtime
            .manifest
            .route_spmm(
                kernel.label(),
                n_bucket,
                reg.csr.rows,
                reg.csr.cols,
                reg.ell_width,
                reg.num_segments,
            )
            .ok_or_else(|| {
                self.metrics.record_error();
                anyhow!(
                    "no {} bucket fits matrix {}x{} (width {}, {} segments) at n={}",
                    kernel.label(),
                    reg.csr.rows,
                    reg.csr.cols,
                    reg.ell_width,
                    reg.num_segments,
                    n_bucket
                )
            })?
            .clone();

        let start = Instant::now();
        let sparse_inputs = self.packed_operands(&reg, &spec)?;
        let k_bucket = spec.param("k").ok_or_else(|| anyhow!("bucket missing k"))?;
        let x_lit = pack::dense_tensor(x, k_bucket, n_bucket)?.to_literal()?;
        let mut inputs: Vec<&xla::Literal> = sparse_inputs.iter().collect();
        inputs.push(&x_lit);
        let outputs = self.runtime.load(&spec.name)?.run_literals(&inputs)?;
        let y = pack::unpack_output(&outputs[0], reg.csr.rows, x.cols)?;
        let latency = start.elapsed();
        self.metrics.record(kernel, latency);
        Ok(SpmmResponse {
            y,
            kernel,
            artifact: spec.name,
            latency,
        })
    }

    /// Packed sparse operands for (matrix, artifact), cached as PJRT
    /// literals: packing AND host→literal conversion are O(bucket), so
    /// they are paid once per (matrix, artifact) and reused across
    /// requests — this is what keeps repeat traffic cheap (§Perf).
    fn packed_operands(
        &self,
        reg: &Registered,
        spec: &ArtifactSpec,
    ) -> Result<Arc<Vec<xla::Literal>>> {
        if let Some(hit) = reg.packed.lock().unwrap().get(&spec.name) {
            return Ok(hit.clone());
        }
        let variant = spec
            .variant
            .as_deref()
            .ok_or_else(|| anyhow!("artifact {} has no variant", spec.name))?;
        let tensors = if variant.ends_with("_rs") {
            let (v, c) = pack::ell_tensors(&reg.csr, spec)?;
            vec![v, c]
        } else {
            let (v, c, r) = pack::segment_tensors(&reg.csr, spec)?;
            vec![v, c, r]
        };
        let literals = tensors
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let arc = Arc::new(literals);
        reg.packed
            .lock()
            .unwrap()
            .insert(spec.name.clone(), arc.clone());
        Ok(arc)
    }

    /// Direct access to the PJRT runtime (GCN trainer, diagnostics).
    pub fn runtime(&self) -> &Engine {
        &self.runtime
    }
}

// Engine tests requiring real artifacts live in rust/tests/.
