//! Operand packing: sparse matrices and dense operands → bucket-shaped
//! tensors for the fixed-shape AOT artifacts.
//!
//! Buckets are zero-padded: ELL rows beyond the matrix get zero values and
//! column 0; segment padding repeats the last real (row, col) with value 0
//! (exactly the Python-side `formats.py` conventions — both sides must
//! agree or the kernels read garbage).

use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::tensor::Tensor;
use crate::sparse::{CsrMatrix, DenseMatrix, EllMatrix, SegmentedMatrix};
use anyhow::{anyhow, Result};

/// ELL planes padded to a bucket: `(values, col_idx)` of shape
/// `(m_pad, width)`.
pub fn ell_tensors(csr: &CsrMatrix, spec: &ArtifactSpec) -> Result<(Tensor, Tensor)> {
    let m_pad = spec.param("m_pad").ok_or_else(|| anyhow!("bucket missing m_pad"))?;
    let width = spec.param("width").ok_or_else(|| anyhow!("bucket missing width"))?;
    if csr.rows > m_pad {
        return Err(anyhow!("matrix rows {} exceed bucket m_pad {m_pad}", csr.rows));
    }
    let ell = EllMatrix::from_csr(csr, 1, 1);
    if ell.width > width {
        return Err(anyhow!("row length {} exceeds bucket width {width}", ell.width));
    }
    let mut values = vec![0f32; m_pad * width];
    let mut cols = vec![0i32; m_pad * width];
    for r in 0..csr.rows {
        let (rc, rv) = csr.row(r);
        for k in 0..rc.len() {
            values[r * width + k] = rv[k];
            cols[r * width + k] = rc[k] as i32;
        }
    }
    Ok((
        Tensor::f32(vec![m_pad, width], values),
        Tensor::i32(vec![m_pad, width], cols),
    ))
}

/// Segment planes padded to a bucket: `(values, col_idx, row_idx)` of
/// shape `(nseg, seg_len)`.
pub fn segment_tensors(csr: &CsrMatrix, spec: &ArtifactSpec) -> Result<(Tensor, Tensor, Tensor)> {
    let nseg = spec.param("nseg").ok_or_else(|| anyhow!("bucket missing nseg"))?;
    let seg_len = spec.param("seg_len").ok_or_else(|| anyhow!("bucket missing seg_len"))?;
    let seg = SegmentedMatrix::from_csr(csr, seg_len);
    if seg.num_segments > nseg {
        return Err(anyhow!(
            "{} segments exceed bucket nseg {nseg}",
            seg.num_segments
        ));
    }
    let padded = nseg * seg_len;
    let mut values = vec![0f32; padded];
    let mut cols = vec![0i32; padded];
    let mut rows = vec![0i32; padded];
    let real = seg.num_segments * seg_len;
    values[..real].copy_from_slice(&seg.values);
    for i in 0..real {
        cols[i] = seg.col_idx[i] as i32;
        rows[i] = seg.row_idx[i] as i32;
    }
    // bucket padding repeats the trailing (row, col) with value 0
    let (pad_c, pad_r) = if real > 0 {
        (cols[real - 1], rows[real - 1])
    } else {
        (0, 0)
    };
    for i in real..padded {
        cols[i] = pad_c;
        rows[i] = pad_r;
    }
    Ok((
        Tensor::f32(vec![nseg, seg_len], values),
        Tensor::i32(vec![nseg, seg_len], cols),
        Tensor::i32(vec![nseg, seg_len], rows),
    ))
}

/// Dense operand padded to the bucket's `(k, n)`.
pub fn dense_tensor(x: &DenseMatrix, k_bucket: usize, n_bucket: usize) -> Result<Tensor> {
    if x.rows > k_bucket || x.cols > n_bucket {
        return Err(anyhow!(
            "dense operand {}x{} exceeds bucket {k_bucket}x{n_bucket}",
            x.rows,
            x.cols
        ));
    }
    let mut data = vec![0f32; k_bucket * n_bucket];
    for r in 0..x.rows {
        data[r * n_bucket..r * n_bucket + x.cols].copy_from_slice(x.row(r));
    }
    Ok(Tensor::f32(vec![k_bucket, n_bucket], data))
}

/// Slice the `(m_pad, n_bucket)` artifact output back to `(rows, n)`.
pub fn unpack_output(out: &Tensor, rows: usize, n: usize) -> Result<DenseMatrix> {
    let shape = out.shape();
    if shape.len() != 2 || shape[0] < rows || shape[1] < n {
        return Err(anyhow!("output shape {:?} cannot contain {rows}x{n}", shape));
    }
    let data = out.as_f32()?;
    let n_bucket = shape[1];
    let mut result = DenseMatrix::zeros(rows, n);
    for r in 0..rows {
        result.data[r * n..(r + 1) * n].copy_from_slice(&data[r * n_bucket..r * n_bucket + n]);
    }
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::CooMatrix;
    use std::collections::BTreeMap;

    fn spec(m_pad: usize, k: usize, width: usize, nseg: usize, seg_len: usize) -> ArtifactSpec {
        let mut params = BTreeMap::new();
        params.insert("m_pad".to_string(), m_pad);
        params.insert("k".to_string(), k);
        params.insert("width".to_string(), width);
        params.insert("nseg".to_string(), nseg);
        params.insert("seg_len".to_string(), seg_len);
        ArtifactSpec {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            kind: "spmm".into(),
            variant: Some("sr_rs".into()),
            bucket: Some("s".into()),
            n: Some(4),
            params,
            inputs: vec![],
            outputs: vec![],
        }
    }

    fn small_csr() -> CsrMatrix {
        let mut coo = CooMatrix::new(3, 5);
        coo.push(0, 1, 1.0);
        coo.push(0, 4, 2.0);
        coo.push(2, 0, 3.0);
        CsrMatrix::from_coo(&coo)
    }

    #[test]
    fn ell_packing_layout() {
        let (v, c) = ell_tensors(&small_csr(), &spec(8, 8, 4, 8, 4)).unwrap();
        assert_eq!(v.shape(), &[8, 4]);
        let vd = v.as_f32().unwrap();
        assert_eq!(&vd[0..2], &[1.0, 2.0]);
        assert_eq!(vd[2], 0.0); // padded slot
        assert!(vd[4..8].iter().all(|&v| v == 0.0)); // empty row 1
        assert_eq!(vd[8], 3.0); // row 2, first slot
        match c {
            Tensor::I32 { data, .. } => {
                assert_eq!(&data[0..2], &[1, 4]);
                assert_eq!(data[8], 0); // row 2 col index
            }
            other => panic!("ell col_idx tensor must be I32, got {other:?}"),
        }
    }

    #[test]
    fn ell_packing_rejects_oversize() {
        assert!(ell_tensors(&small_csr(), &spec(2, 8, 4, 8, 4)).is_err()); // rows
        assert!(ell_tensors(&small_csr(), &spec(8, 8, 1, 8, 4)).is_err()); // width
    }

    #[test]
    fn segment_packing_pads_with_trailing_row() {
        let (v, c, r) = segment_tensors(&small_csr(), &spec(8, 8, 4, 4, 2)).unwrap();
        assert_eq!(v.shape(), &[4, 2]);
        let vd = v.as_f32().unwrap();
        assert_eq!(&vd[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(vd[3], 0.0);
        match (c, r) {
            (Tensor::I32 { data: cd, .. }, Tensor::I32 { data: rd, .. }) => {
                // padding repeats (row 2, col 0)
                assert!(cd[3..].iter().all(|&x| x == 0));
                assert!(rd[3..].iter().all(|&x| x == 2));
            }
            (c, r) => panic!("segment col_idx/row_idx tensors must be I32, got {c:?} / {r:?}"),
        }
    }

    #[test]
    fn dense_pack_unpack_roundtrip() {
        let x = DenseMatrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let t = dense_tensor(&x, 4, 8).unwrap();
        assert_eq!(t.shape(), &[4, 8]);
        let back = unpack_output(&t, 2, 3).unwrap();
        assert_eq!(back, x);
        assert!(dense_tensor(&x, 1, 8).is_err());
    }
}
