//! Request-loop server: a channel-fed worker thread that batches and
//! executes SpMM requests (the deployment shape of the coordinator).
//!
//! Uses std mpsc — the offline registry has no tokio; the loop is the
//! same select-batch-execute structure a tokio runtime would drive.

use super::batcher::{BatchedResult, Batcher};
use super::engine::{MatrixHandle, SpmmEngine};
use crate::sparse::DenseMatrix;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// A request into the server.
pub struct Request {
    pub matrix: MatrixHandle,
    pub x: DenseMatrix,
    pub tag: u64,
    /// where the result is delivered
    pub reply: mpsc::Sender<ServerReply>,
}

/// Result delivered to the requester.
#[derive(Debug)]
pub enum ServerReply {
    Ok(BatchedResult),
    Err(String),
}

/// Server configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// max combined dense width before a batch is forced out
    pub max_width: usize,
    /// flush deadline for partially-filled batches
    pub max_delay: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_width: 128,
            max_delay: Duration::from_millis(2),
        }
    }
}

/// Run the request loop until the channel closes. Intended to be spawned
/// on a worker thread with the engine shared by reference.
pub fn serve(engine: &SpmmEngine, rx: mpsc::Receiver<Request>, config: ServerConfig) {
    let mut batcher = Batcher::new(engine, config.max_width);
    let mut repliers: std::collections::HashMap<u64, mpsc::Sender<ServerReply>> =
        std::collections::HashMap::new();
    let mut deadline: Option<Instant> = None;

    let deliver = |results: Vec<BatchedResult>,
                   repliers: &mut std::collections::HashMap<u64, mpsc::Sender<ServerReply>>| {
        for r in results {
            if let Some(tx) = repliers.remove(&r.tag) {
                let _ = tx.send(ServerReply::Ok(r));
            }
        }
    };

    loop {
        let timeout = deadline
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_secs(3600));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                repliers.insert(req.tag, req.reply.clone());
                match batcher.submit(req.matrix, req.x, req.tag) {
                    Ok(results) => deliver(results, &mut repliers),
                    Err(e) => {
                        if let Some(tx) = repliers.remove(&req.tag) {
                            let _ = tx.send(ServerReply::Err(e.to_string()));
                        }
                    }
                }
                if batcher.pending() > 0 && deadline.is_none() {
                    deadline = Some(Instant::now() + config.max_delay);
                }
                if batcher.pending() == 0 {
                    deadline = None;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // deadline reached: flush partial batches
                match batcher.flush_all() {
                    Ok(results) => deliver(results, &mut repliers),
                    Err(e) => {
                        // deliver the error to everyone still waiting
                        for (_, tx) in repliers.drain() {
                            let _ = tx.send(ServerReply::Err(e.to_string()));
                        }
                    }
                }
                deadline = None;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                let _ = batcher.flush_all().map(|r| deliver(r, &mut repliers));
                return;
            }
        }
    }
}

// End-to-end server tests (needing artifacts) live in rust/tests/.
