//! Concurrent serving layer: a multi-worker request path over one shared
//! [`SpmmEngine`].
//!
//! [`Server::start`] spawns `N` worker threads, each running the
//! select-batch-execute loop over its own [`Batcher`]. Requests are
//! **op-tagged** ([`RequestOp`]): SpMM requests batch along the
//! dense-width axis, SDDMM requests execute unbatched through the same
//! admission/reply/failure-isolation path, and both share the engine's
//! prepared-matrix state per registered graph. Requests are
//! routed to workers **by registration identity**
//! ([`SpmmEngine::batch_key`]: content fingerprint on a cached engine),
//! so one matrix's stream — even across clients holding distinct handles
//! to the same graph — lands on one worker, whose batcher coalesces it
//! along the dense-width axis, while distinct matrices execute on
//! different workers in parallel.
//! [`Server::submit`] enforces the [`ServerConfig::max_queue`] admission
//! bound: past it, requests are refused immediately with a
//! [`ServerReply::Err`] instead of queueing without bound, and the
//! refusal is counted in the engine's
//! [`Metrics`](super::metrics::Metrics). [`Server::shutdown`] (or drop)
//! disconnects the workers, which flush their partial batches and exit —
//! no admitted request is abandoned.
//!
//! [`serve`] remains the single-threaded loop (one worker driven on the
//! caller's thread) for callers that own the receiving end, e.g. an
//! engine pinned to its thread by a `!Send` PJRT client. Uses std mpsc —
//! the offline registry has no tokio; the loop is the same structure a
//! tokio runtime would drive. See `DESIGN.md` §Serving layer.
//!
//! The server is selector-agnostic: over an engine built with
//! [`SpmmEngine::serving_online`], the traffic these workers drive is
//! exactly what feeds the online selector's cost EWMAs and threshold
//! refits (`DESIGN.md` §Measured calibration) — no server-side wiring
//! is needed.

use super::batcher::{BatchedResult, Batcher, FlushOutcome};
use super::engine::{MatrixHandle, SpmmEngine};
use crate::obs::trace::Trace;
use crate::sparse::DenseMatrix;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// The op-tagged payload of one request: which sparse op to run and its
/// dense operands.
pub enum RequestOp {
    /// `Y = A · X` — batched along the dense-width axis.
    Spmm {
        /// The dense operand `X`.
        x: DenseMatrix,
    },
    /// `S = sample(A, U·Vᵀ)` — executed unbatched (each request carries
    /// its own `(U, V)` pair; there is no width axis to coalesce).
    Sddmm {
        /// The left dense operand `U` (rows × d).
        u: DenseMatrix,
        /// The right dense operand `V` (cols × d).
        v: DenseMatrix,
    },
}

/// A request into the server.
pub struct Request {
    /// Handle of a matrix registered on the serving engine.
    pub matrix: MatrixHandle,
    /// The sparse op to run and its dense operands.
    pub op: RequestOp,
    /// Caller-chosen correlation id; it keys the reply routing, so it
    /// must be unique among in-flight requests — a duplicate is rejected
    /// with a [`ServerReply::Err`] rather than silently orphaning the
    /// earlier requester.
    pub tag: u64,
    /// Where the result is delivered.
    pub reply: mpsc::Sender<ServerReply>,
    /// Request-lifecycle trace, created at admission by
    /// [`Server::submit`] (its epoch marks the admission instant; the
    /// queue wait surfaces as the `admission` span). `None` for requests
    /// fed directly into [`serve`] — the engine still records
    /// dispatch-level traces for those.
    trace: Option<Arc<Trace>>,
    /// Admission instant — the SLO monitor's latency clock runs from
    /// here to reply delivery. Constructors seed it at creation;
    /// [`Server::submit`] restamps it at admission.
    admitted_at: Instant,
    /// In-flight depth observed at admission (set by [`Server::submit`];
    /// 0 for direct [`serve`] callers) — the SLO queue-objective input.
    admitted_depth: usize,
}

impl Request {
    /// An SpMM request (`Y = A · X`).
    pub fn spmm(
        matrix: MatrixHandle,
        x: DenseMatrix,
        tag: u64,
        reply: mpsc::Sender<ServerReply>,
    ) -> Request {
        Request {
            matrix,
            op: RequestOp::Spmm { x },
            tag,
            reply,
            trace: None,
            admitted_at: Instant::now(),
            admitted_depth: 0,
        }
    }

    /// An SDDMM request (`S = sample(A, U·Vᵀ)`). The reply's
    /// [`BatchedResult::y`] carries the sampled values as an `nnz × 1`
    /// column.
    pub fn sddmm(
        matrix: MatrixHandle,
        u: DenseMatrix,
        v: DenseMatrix,
        tag: u64,
        reply: mpsc::Sender<ServerReply>,
    ) -> Request {
        Request {
            matrix,
            op: RequestOp::Sddmm { u, v },
            tag,
            reply,
            trace: None,
            admitted_at: Instant::now(),
            admitted_depth: 0,
        }
    }
}

/// Result delivered to the requester.
#[derive(Debug)]
pub enum ServerReply {
    /// The batched execution result for this request's tag.
    Ok(BatchedResult),
    /// The request failed (execution error, admission refusal, or a
    /// worker becoming unavailable).
    Err(String),
}

/// Serving-layer configuration: batching, concurrency and admission.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Max combined dense width queued on one matrix before its batch is
    /// forced out (should equal the widest artifact bucket on
    /// fixed-width backends).
    pub max_width: usize,
    /// Flush deadline for partially-filled batches: the longest a
    /// request waits for co-batchable traffic before executing anyway.
    pub max_delay: Duration,
    /// Worker threads spawned by [`Server::start`]. Each owns its own
    /// [`Batcher`]; requests route to a worker by registration identity
    /// ([`SpmmEngine::batch_key`]), so one matrix's traffic coalesces
    /// while distinct matrices parallelize.
    pub workers: usize,
    /// Admission bound: max in-flight (admitted, unanswered) requests
    /// across all workers. Submissions past it are refused immediately
    /// with a [`ServerReply::Err`] — backpressure instead of unbounded
    /// queue growth.
    pub max_queue: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            max_width: 128,
            max_delay: Duration::from_millis(2),
            workers: 4,
            max_queue: 1024,
        }
    }
}

/// Decrement an in-flight counter, saturating at zero (the [`serve`]
/// entry point drives the loop with a counter nothing increments).
fn release(depth: &AtomicUsize) {
    let _ = depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| d.checked_sub(1));
}

/// Per-tag reply routing plus the admission context the SLO monitor
/// needs when the reply finally goes out.
struct Replier {
    tx: mpsc::Sender<ServerReply>,
    admitted_at: Instant,
    admitted_depth: usize,
}

/// One worker's request loop: receive, batch per matrix, flush on width
/// or deadline, deliver replies, and release admission slots as requests
/// complete. Runs until the channel closes, then flushes what's pending.
fn worker_loop(
    engine: &SpmmEngine,
    rx: mpsc::Receiver<Request>,
    config: ServerConfig,
    depth: &AtomicUsize,
) {
    let mut batcher = Batcher::new(engine, config.max_width);
    let mut repliers: HashMap<u64, Replier> = HashMap::new();
    let mut deadline: Option<Instant> = None;
    // SLO monitors install at startup (before workers spawn), so one
    // fetch per worker suffices.
    let slo = engine.metrics.slo();

    // Answer every request a flush settled — successes and per-batch
    // failures alike — and release its admission slot. `FlushError`
    // carries the tags its batch consumed, so no replier can leak.
    // Successful completions feed the SLO monitor (admission-to-reply
    // wall latency plus admission-time queue depth); failures don't —
    // an error reply is an availability event, not a latency sample.
    let deliver = |outcome: FlushOutcome, repliers: &mut HashMap<u64, Replier>| {
        for r in outcome.results {
            if let Some(rep) = repliers.remove(&r.tag) {
                release(depth);
                if let Some(m) = &slo {
                    m.observe(rep.admitted_at.elapsed(), rep.admitted_depth);
                }
                let _ = rep.tx.send(ServerReply::Ok(r));
            }
        }
        for f in outcome.failures {
            let msg = f.error.to_string();
            for tag in f.tags {
                if let Some(rep) = repliers.remove(&tag) {
                    release(depth);
                    let _ = rep.tx.send(ServerReply::Err(msg.clone()));
                }
            }
        }
    };

    loop {
        let timeout = deadline
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_secs(3600));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if repliers.contains_key(&req.tag) {
                    // tag collision with an in-flight request: reject this
                    // one rather than orphan the earlier requester and
                    // leak its admission slot
                    release(depth);
                    let _ = req.reply.send(ServerReply::Err(format!(
                        "duplicate in-flight tag {}",
                        req.tag
                    )));
                } else {
                    let Request {
                        matrix,
                        op,
                        tag,
                        reply,
                        trace,
                        admitted_at,
                        admitted_depth,
                    } = req;
                    repliers.insert(
                        tag,
                        Replier {
                            tx: reply,
                            admitted_at,
                            admitted_depth,
                        },
                    );
                    // Queue wait: the trace epoch is the admission
                    // instant, so [0, now] is exactly how long the
                    // request sat between submit and dequeue.
                    if let Some(t) = &trace {
                        t.record_raw("admission", 0, t.elapsed_ns(), vec![("tag", tag.to_string())]);
                    }
                    let submitted = match op {
                        RequestOp::Spmm { x } => batcher.submit_traced(matrix, x, tag, trace),
                        RequestOp::Sddmm { u, v } => {
                            batcher.submit_sddmm_traced(matrix, u, v, tag, trace)
                        }
                    };
                    match submitted {
                        Ok(outcome) => deliver(outcome, &mut repliers),
                        Err(e) => {
                            // pre-queue validation failure: this request
                            // alone was rejected, nothing else was touched
                            if let Some(rep) = repliers.remove(&tag) {
                                release(depth);
                                let _ = rep.tx.send(ServerReply::Err(e.to_string()));
                            }
                        }
                    }
                }
                if batcher.pending() > 0 && deadline.is_none() {
                    deadline = Some(Instant::now() + config.max_delay);
                }
                if batcher.pending() == 0 {
                    deadline = None;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // deadline reached: flush partial batches
                deliver(batcher.flush_all(), &mut repliers);
                deadline = None;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                deliver(batcher.flush_all(), &mut repliers);
                return;
            }
        }
    }
}

/// Run the request loop until the channel closes, on the caller's
/// thread. This is the single-worker deployment shape (and what each
/// [`Server`] worker runs internally); use it directly when the engine
/// cannot leave the current thread, e.g. over a `!Send` PJRT client.
pub fn serve(engine: &SpmmEngine, rx: mpsc::Receiver<Request>, config: ServerConfig) {
    worker_loop(engine, rx, config, &AtomicUsize::new(0));
}

/// Handle to a running multi-worker server over a shared [`SpmmEngine`].
///
/// Producers call [`Server::submit`] from any thread; replies arrive on
/// each request's own channel. Dropping the handle (or calling
/// [`Server::shutdown`]) stops admission, lets the workers drain and
/// flush, and joins them.
pub struct Server {
    engine: Arc<SpmmEngine>,
    txs: Vec<mpsc::Sender<Request>>,
    workers: Vec<JoinHandle<()>>,
    depth: Arc<AtomicUsize>,
    max_queue: usize,
}

impl Server {
    /// Spawn `config.workers` worker threads (at least one) over a shared
    /// engine and start accepting submissions.
    pub fn start(engine: Arc<SpmmEngine>, config: ServerConfig) -> Server {
        let nworkers = config.workers.max(1);
        let depth = Arc::new(AtomicUsize::new(0));
        let mut txs = Vec::with_capacity(nworkers);
        let mut workers = Vec::with_capacity(nworkers);
        for _ in 0..nworkers {
            let (tx, rx) = mpsc::channel::<Request>();
            txs.push(tx);
            let engine = engine.clone();
            let depth = depth.clone();
            workers.push(std::thread::spawn(move || {
                let ran = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    worker_loop(&engine, rx, config, &depth);
                }));
                if ran.is_err() {
                    // surface the crash immediately: this worker's
                    // in-flight requests are lost and its share of the
                    // routing space goes unserved until shutdown
                    eprintln!("ge-spmm server: worker thread panicked");
                }
            }));
        }
        Server {
            engine,
            txs,
            workers,
            depth,
            max_queue: config.max_queue.max(1),
        }
    }

    /// Submit a request. Routed by the engine's
    /// [`batch_key`](SpmmEngine::batch_key) — the registration identity —
    /// so one matrix's stream (including content-identical handles from
    /// other clients, on a cached engine) lands on one worker, whose
    /// batcher coalesces it, while distinct matrices spread across
    /// workers. Returns `false` — after delivering a
    /// [`ServerReply::Err`] on the request's reply channel and counting
    /// the refusal in the engine metrics — when the admission bound is
    /// hit or the target worker is gone.
    pub fn submit(&self, req: Request) -> bool {
        let mut req = req;
        let admitted = self.depth.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
            if d < self.max_queue {
                Some(d + 1)
            } else {
                None
            }
        });
        let previous = match admitted {
            Ok(previous) => previous,
            Err(_) => {
                self.engine.metrics.record_rejection();
                let _ = req.reply.send(ServerReply::Err(format!(
                    "server at capacity ({} requests in flight)",
                    self.max_queue
                )));
                return false;
            }
        };
        self.engine.metrics.record_queue_depth(previous + 1);
        req.admitted_at = Instant::now();
        req.admitted_depth = previous + 1;
        // Start the request-lifecycle trace at the admission instant:
        // its epoch is t=0 for every span the request accrues downstream
        // (queue wait, batch, dispatch, shard fan-out, kernels).
        let label = match &req.op {
            RequestOp::Spmm { .. } => format!("spmm#{}", req.tag),
            RequestOp::Sddmm { .. } => format!("sddmm#{}", req.tag),
        };
        req.trace = Some(Trace::begin(label));
        // unknown handles route anywhere; the worker's batcher rejects
        // them individually at validation
        let key = self.engine.batch_key(req.matrix).unwrap_or(u64::MAX);
        let worker = (key % self.txs.len() as u64) as usize;
        if let Err(mpsc::SendError(req)) = self.txs[worker].send(req) {
            // worker gone: undo the admission and surface the failure
            release(&self.depth);
            self.engine.metrics.record_rejection();
            let _ = req
                .reply
                .send(ServerReply::Err("server worker unavailable".to_string()));
            return false;
        }
        true
    }

    /// Requests currently admitted but not yet answered.
    pub fn in_flight(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Graceful shutdown: stop accepting, let every worker drain its
    /// queue and flush partial batches, then join. Equivalent to
    /// dropping the handle, but explicit at call sites.
    pub fn shutdown(mut self) {
        self.finish();
    }

    fn finish(&mut self) {
        self.txs.clear(); // disconnect → workers flush and exit
        for w in self.workers.drain(..) {
            // worker threads catch and report their own panics
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.finish();
    }
}

// End-to-end server tests live in rust/tests/: native_coordinator.rs
// (single worker, artifact-free), serving_cache.rs (multi-worker, cache,
// admission), integration_coordinator.rs (PJRT artifacts).
