//! Coordinator metrics: request counts, per-kernel selection counts,
//! latency histograms, and the observability hub. Lock-free on the hot
//! path — every counter is a relaxed atomic and every latency
//! distribution is a log-bucketed [`AtomicHistogram`]; the only mutexes
//! are inside the bounded flight-recorder and audit rings (one short,
//! poison-tolerant acquisition per request), so a panicking worker can
//! never wedge stats for the whole server.
//!
//! Since the kernel layer became a generated variant space
//! ([`crate::kernels::generator`]), every bank here is **registry-indexed
//! and runtime-sized**: one slot per [`crate::kernels::VariantEntry`],
//! `registry().len()` wide, indexed by dense variant id. The former
//! `KernelKind::ALL`-ordered `[...; 4]` arrays (and their
//! `position().unwrap()` index fn) are gone — family-level views
//! ([`Metrics::kernel_counts`], [`Metrics::latency_histogram`], ...)
//! survive as **aggregations** over a family's variants, so the paper's
//! 2×2 observability surface is unchanged while per-variant resolution
//! is available underneath ([`Metrics::variant_request_count`],
//! [`Metrics::latency_histogram_variant`]). Variant ids are validated on
//! every entry point: an unknown id is a `false`/`None` return, never a
//! panic.
//!
//! Requests and shards are counted separately: one sharded request fans
//! out into K shard executions, each with its own kernel choice and
//! wallclock. The `shard_*` counters are how per-shard adaptivity is
//! observed from outside (`crate::shard::ShardedBackend` records them).
//! The two sparse ops stay **tagged apart**: SpMM and SDDMM variants
//! occupy disjoint id ranges of the same registry, so one bank per grain
//! serves both ops while per-op totals and the per-op family counters
//! remain separately observable (attention workloads mix the FusedMM
//! pair — `DESIGN.md` §SDDMM). Latency quantiles come per
//! **op × grain × kernel** from the histogram banks
//! ([`Metrics::latency_histogram`]); the exposition surface
//! (`crate::obs::expo`) renders them as Prometheus text and JSON.
//!
//! `Metrics` is also the hub the rest of the observability subsystem
//! hangs off: the request-trace [`FlightRecorder`], the selector
//! decision [`AuditLog`], the selector-regret [`RegretTracker`] and the
//! optional serving [`SloMonitor`] live here because every layer that
//! needs them (engine, server, batcher, sharded backend) already shares
//! one `Arc<Metrics>`.
//!
//! The **workload banks** turn the same dispatch stream into roofline
//! accounting: every native execution reports its analytic
//! [`WorkloadEstimate`] (flops, bytes moved, segment padding — see
//! [`crate::obs::workload`]) alongside its wallclock, accumulated per
//! variant id, so `ge-spmm stats` can print achieved GFLOP/s, GB/s and
//! arithmetic intensity per (op, variant) without any sampling. Shard
//! fan-outs additionally record a per-batch **nnz imbalance** ratio
//! (max/mean over the batch's shards, in integer milli-units) — the
//! paper's workload-balancing claim as a measured distribution.
//!
//! The per-`(feature bucket, variant)` cost EWMAs
//! ([`Metrics::observe_cost_variant`] / [`Metrics::cost_variant`]) are
//! the substrate of online selector refinement: executions report
//! normalized latencies here, [`crate::selector::OnlineSelector`] refits
//! its family thresholds against the family view ([`Metrics::cost`] =
//! the family's best variant estimate) and picks within-family winners
//! from the per-variant cells (`DESIGN.md` §Kernel generation).

use crate::kernels::generator::registry;
use crate::kernels::{KernelKind, SparseOp};
use crate::obs::audit::AuditLog;
use crate::obs::hist::{AtomicHistogram, HistogramSnapshot};
use crate::obs::regret::RegretTracker;
use crate::obs::slo::SloMonitor;
use crate::obs::trace::FlightRecorder;
use crate::obs::workload::{WorkloadEstimate, WorkloadTotals};
use crate::obs::Grain;
use crate::selector::online::SDDMM_BUCKETS;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Number of feature buckets the per-variant cost EWMAs are keyed by.
/// The bucketing function lives in [`crate::selector::online`]
/// (`feature_bucket`); `Metrics` only stores the table.
pub const COST_BUCKETS: usize = 12;

/// EWMA smoothing factor for the cost table: each observation moves the
/// estimate 25% toward itself — reactive enough for online refinement,
/// damped enough to ride out scheduler noise.
pub const COST_EWMA_ALPHA: f64 = 0.25;

/// Aggregate metrics for an engine instance. Every per-kernel bank is
/// registry-indexed (one slot per generated variant, sized at
/// construction); build via `Default`.
#[derive(Debug)]
pub struct Metrics {
    requests: AtomicU64,
    errors: AtomicU64,
    /// total SpMM execution nanoseconds
    exec_ns: AtomicU64,
    /// request-grain selections per variant id (both ops — ids are
    /// op-disjoint by registry construction)
    request_by_variant: Vec<AtomicU64>,
    /// request-grain latency histograms, one per variant id
    request_hist: Vec<AtomicHistogram>,
    /// shard-level counters (sharded backends only; zero otherwise)
    shard_execs: AtomicU64,
    shard_ns: AtomicU64,
    /// slowest single shard execution seen — the fan-out straggler bound
    shard_max_ns: AtomicU64,
    shard_by_variant: Vec<AtomicU64>,
    shard_hist: Vec<AtomicHistogram>,
    /// SDDMM totals — kept apart from the SpMM totals so per-op latency
    /// means stay meaningful when traffic mixes the ops
    sddmm_requests: AtomicU64,
    sddmm_ns: AtomicU64,
    sddmm_shard_execs: AtomicU64,
    sddmm_shard_ns: AtomicU64,
    /// partial re-preparation outcomes for sharded structural deltas:
    /// prepared shard operands carried over verbatim vs. rebuilt
    shard_reused: AtomicU64,
    shard_reprepared: AtomicU64,
    /// prepared-matrix cache counters (engines with a cache only)
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_evictions: AtomicU64,
    /// serving-layer admission counters (multi-worker `Server` only)
    rejected: AtomicU64,
    /// high-water mark of in-flight requests observed at admission
    queue_depth_max: AtomicU64,
    /// per-(feature-bucket, variant) EWMA of normalized execution cost
    /// (seconds per flop), stored as f64 bits; row-major,
    /// `bucket * registry().len() + variant`
    cost_ewma: Vec<AtomicU64>,
    /// observation counts behind each EWMA cell (0 = cell is empty)
    cost_obs: Vec<AtomicU64>,
    /// per-variant workload accounting: executions, nanoseconds, flops,
    /// bytes read/written, padding bytes, rows and nnz processed —
    /// registry-indexed like every other bank
    wl_execs: Vec<AtomicU64>,
    wl_ns: Vec<AtomicU64>,
    wl_flops: Vec<AtomicU64>,
    wl_bytes_read: Vec<AtomicU64>,
    wl_bytes_written: Vec<AtomicU64>,
    wl_padding: Vec<AtomicU64>,
    wl_rows: Vec<AtomicU64>,
    wl_nnz: Vec<AtomicU64>,
    /// per-batch shard nnz imbalance (max/mean, integer milli-ratio):
    /// batch count, ratio sum, and the worst batch seen
    imbalance_batches: AtomicU64,
    imbalance_milli_sum: AtomicU64,
    imbalance_milli_max: AtomicU64,
    /// ring of the last N request traces (committed at request end)
    recorder: Arc<FlightRecorder>,
    /// ring of recent selector decisions with features and thresholds
    audit: Arc<AuditLog>,
    /// running selector-regret counters, folded by the online selector
    regret: Arc<RegretTracker>,
    /// serving SLO monitor, installed by `serve --slo` (absent
    /// otherwise); behind a mutex because installation happens once at
    /// startup while readers snapshot the `Arc`
    slo: Mutex<Option<Arc<SloMonitor>>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::with_trace_capacity(crate::obs::trace::DEFAULT_TRACE_CAPACITY)
    }
}

impl Metrics {
    /// Build a metrics hub whose flight recorder keeps the last
    /// `trace_capacity` request traces (the `Default` impl uses the
    /// recorder's stock capacity). Every bank is sized off the live
    /// variant registry.
    pub fn with_trace_capacity(trace_capacity: usize) -> Self {
        let nv = registry().len();
        let counters = |n: usize| (0..n).map(|_| AtomicU64::new(0)).collect::<Vec<_>>();
        let hists = |n: usize| (0..n).map(|_| AtomicHistogram::new()).collect::<Vec<_>>();
        Self {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            exec_ns: AtomicU64::new(0),
            request_by_variant: counters(nv),
            request_hist: hists(nv),
            shard_execs: AtomicU64::new(0),
            shard_ns: AtomicU64::new(0),
            shard_max_ns: AtomicU64::new(0),
            shard_by_variant: counters(nv),
            shard_hist: hists(nv),
            sddmm_requests: AtomicU64::new(0),
            sddmm_ns: AtomicU64::new(0),
            sddmm_shard_execs: AtomicU64::new(0),
            sddmm_shard_ns: AtomicU64::new(0),
            shard_reused: AtomicU64::new(0),
            shard_reprepared: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            cache_misses: AtomicU64::new(0),
            cache_evictions: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            queue_depth_max: AtomicU64::new(0),
            cost_ewma: counters(COST_BUCKETS * nv),
            cost_obs: counters(COST_BUCKETS * nv),
            wl_execs: counters(nv),
            wl_ns: counters(nv),
            wl_flops: counters(nv),
            wl_bytes_read: counters(nv),
            wl_bytes_written: counters(nv),
            wl_padding: counters(nv),
            wl_rows: counters(nv),
            wl_nnz: counters(nv),
            imbalance_batches: AtomicU64::new(0),
            imbalance_milli_sum: AtomicU64::new(0),
            imbalance_milli_max: AtomicU64::new(0),
            recorder: Arc::new(FlightRecorder::new(trace_capacity)),
            audit: Arc::default(),
            regret: Arc::new(RegretTracker::new(COST_BUCKETS, SDDMM_BUCKETS, nv)),
            slo: Mutex::new(None),
        }
    }

    /// Sum one variant-indexed bank over a family's variants of one op.
    fn family_sum(&self, bank: &[AtomicU64], op: SparseOp, family: KernelKind) -> u64 {
        registry()
            .family_variants(op, family)
            .iter()
            .map(|e| bank[e.id].load(Ordering::Relaxed))
            .sum()
    }

    fn four_families(&self, bank: &[AtomicU64], op: SparseOp) -> [u64; 4] {
        KernelKind::ALL.map(|k| self.family_sum(bank, op, k))
    }

    /// Record one completed request under a specific **variant id**.
    /// Routes to the SpMM or SDDMM totals by the variant's op tag;
    /// returns `false` (recording nothing) for an unknown id.
    pub fn record_request_variant(&self, variant: usize, latency: Duration) -> bool {
        let Some(entry) = registry().get(variant) else {
            return false;
        };
        let ns = latency.as_nanos() as u64;
        match entry.variant.op {
            SparseOp::Spmm => {
                self.requests.fetch_add(1, Ordering::Relaxed);
                self.exec_ns.fetch_add(ns, Ordering::Relaxed);
            }
            SparseOp::Sddmm => {
                self.sddmm_requests.fetch_add(1, Ordering::Relaxed);
                self.sddmm_ns.fetch_add(ns, Ordering::Relaxed);
            }
        }
        self.request_by_variant[variant].fetch_add(1, Ordering::Relaxed);
        self.request_hist[variant].record_duration(latency);
        true
    }

    /// Record one shard execution under a specific **variant id**.
    /// Returns `false` (recording nothing) for an unknown id.
    pub fn record_shard_variant(&self, variant: usize, latency: Duration) -> bool {
        let Some(entry) = registry().get(variant) else {
            return false;
        };
        let ns = latency.as_nanos() as u64;
        match entry.variant.op {
            SparseOp::Spmm => {
                self.shard_execs.fetch_add(1, Ordering::Relaxed);
                self.shard_ns.fetch_add(ns, Ordering::Relaxed);
                self.shard_max_ns.fetch_max(ns, Ordering::Relaxed);
            }
            SparseOp::Sddmm => {
                self.sddmm_shard_execs.fetch_add(1, Ordering::Relaxed);
                self.sddmm_shard_ns.fetch_add(ns, Ordering::Relaxed);
            }
        }
        self.shard_by_variant[variant].fetch_add(1, Ordering::Relaxed);
        self.shard_hist[variant].record_duration(latency);
        true
    }

    /// Record one completed SpMM request at family grain — lands on the
    /// family's canonical variant slot.
    pub fn record(&self, kernel: KernelKind, latency: Duration) {
        self.record_request_variant(registry().canonical_id(SparseOp::Spmm, kernel), latency);
    }

    /// Record a failed request.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one SpMM shard execution inside a sharded request. `kernel`
    /// is the shard's own choice, which in adaptive mode may differ from
    /// the request-level kernel recorded by [`Metrics::record`].
    pub fn record_shard(&self, kernel: KernelKind, latency: Duration) {
        self.record_shard_variant(registry().canonical_id(SparseOp::Spmm, kernel), latency);
    }

    /// Completed request count.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Error count.
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// SpMM requests per family, in [`KernelKind::ALL`] order — each
    /// entry sums the family's variants.
    pub fn kernel_counts(&self) -> [u64; 4] {
        self.four_families(&self.request_by_variant, SparseOp::Spmm)
    }

    /// Request-grain selections of one variant id (0 for unknown ids).
    pub fn variant_request_count(&self, variant: usize) -> u64 {
        self.request_by_variant
            .get(variant)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Shard-grain selections of one variant id (0 for unknown ids).
    pub fn variant_shard_count(&self, variant: usize) -> u64 {
        self.shard_by_variant
            .get(variant)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Mean execution latency.
    pub fn mean_latency(&self) -> Duration {
        let n = self.requests();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.exec_ns.load(Ordering::Relaxed) / n)
    }

    /// Shard executions recorded (0 unless a sharded backend is in use).
    pub fn shard_executions(&self) -> u64 {
        self.shard_execs.load(Ordering::Relaxed)
    }

    /// SpMM shard executions per family, in [`KernelKind::ALL`] order —
    /// the observable trace of per-shard adaptive choices.
    pub fn shard_kernel_counts(&self) -> [u64; 4] {
        self.four_families(&self.shard_by_variant, SparseOp::Spmm)
    }

    /// Mean single-shard execution latency.
    pub fn shard_mean_latency(&self) -> Duration {
        let n = self.shard_executions();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.shard_ns.load(Ordering::Relaxed) / n)
    }

    /// Slowest single-shard execution — the straggler that bounds fan-out
    /// wallclock.
    pub fn shard_max_latency(&self) -> Duration {
        Duration::from_nanos(self.shard_max_ns.load(Ordering::Relaxed))
    }

    /// Record the outcome of one sharded structural re-preparation:
    /// `reused` prepared shard operands carried over verbatim and
    /// `reprepared` rebuilt from their re-cut row slices.
    pub fn record_shard_reuse(&self, reused: u64, reprepared: u64) {
        if reused > 0 {
            self.shard_reused.fetch_add(reused, Ordering::Relaxed);
        }
        if reprepared > 0 {
            self.shard_reprepared.fetch_add(reprepared, Ordering::Relaxed);
        }
    }

    /// Prepared shard operands reused verbatim across structural deltas.
    pub fn shard_operands_reused(&self) -> u64 {
        self.shard_reused.load(Ordering::Relaxed)
    }

    /// Prepared shard operands rebuilt across structural deltas.
    pub fn shard_operands_reprepared(&self) -> u64 {
        self.shard_reprepared.load(Ordering::Relaxed)
    }

    /// Record one completed SDDMM request at family grain. Op-tagged
    /// apart from [`Metrics::record`] so SpMM and SDDMM kernel selection
    /// are observable per op.
    pub fn record_sddmm(&self, kernel: KernelKind, latency: Duration) {
        self.record_request_variant(registry().canonical_id(SparseOp::Sddmm, kernel), latency);
    }

    /// Record one SDDMM shard execution inside a sharded request.
    pub fn record_sddmm_shard(&self, kernel: KernelKind, latency: Duration) {
        self.record_shard_variant(registry().canonical_id(SparseOp::Sddmm, kernel), latency);
    }

    /// Completed SDDMM request count.
    pub fn sddmm_requests(&self) -> u64 {
        self.sddmm_requests.load(Ordering::Relaxed)
    }

    /// SDDMM requests per family, in [`KernelKind::ALL`] order — the
    /// per-op selection counter the serving layer exposes.
    pub fn sddmm_kernel_counts(&self) -> [u64; 4] {
        self.four_families(&self.request_by_variant, SparseOp::Sddmm)
    }

    /// Mean SDDMM execution latency.
    pub fn sddmm_mean_latency(&self) -> Duration {
        let n = self.sddmm_requests();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sddmm_ns.load(Ordering::Relaxed) / n)
    }

    /// SDDMM shard executions recorded (0 unless a sharded backend ran
    /// the op).
    pub fn sddmm_shard_executions(&self) -> u64 {
        self.sddmm_shard_execs.load(Ordering::Relaxed)
    }

    /// SDDMM shard executions per family, in [`KernelKind::ALL`] order —
    /// the observable trace of per-shard adaptive SDDMM choices.
    pub fn sddmm_shard_kernel_counts(&self) -> [u64; 4] {
        self.four_families(&self.shard_by_variant, SparseOp::Sddmm)
    }

    /// Mean single-shard SDDMM execution latency.
    pub fn sddmm_shard_mean_latency(&self) -> Duration {
        let n = self.sddmm_shard_executions();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sddmm_shard_ns.load(Ordering::Relaxed) / n)
    }

    /// Record a prepared-matrix cache hit (registration skipped prepare).
    pub fn record_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a prepared-matrix cache miss (registration paid prepare).
    pub fn record_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` cache evictions caused by one insertion.
    pub fn record_cache_evictions(&self, n: u64) {
        if n > 0 {
            self.cache_evictions.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Record a request refused at admission (server at capacity).
    pub fn record_rejection(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the in-flight request count observed at one admission;
    /// keeps the high-water mark.
    pub fn record_queue_depth(&self, depth: usize) {
        self.queue_depth_max.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Prepared-matrix cache hits (registrations that skipped prepare).
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Prepared-matrix cache misses (registrations that paid prepare).
    pub fn cache_misses(&self) -> u64 {
        self.cache_misses.load(Ordering::Relaxed)
    }

    /// Entries evicted from the prepared-matrix cache so far.
    pub fn cache_evictions(&self) -> u64 {
        self.cache_evictions.load(Ordering::Relaxed)
    }

    /// Requests refused at admission (server at capacity).
    pub fn rejections(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// High-water mark of in-flight requests observed at admission.
    pub fn max_queue_depth(&self) -> u64 {
        self.queue_depth_max.load(Ordering::Relaxed)
    }

    /// Flat index of one `(bucket, variant)` cost cell, or `None` when
    /// either index is out of range.
    fn cost_cell(&self, bucket: usize, variant: usize) -> Option<usize> {
        let nv = registry().len();
        if bucket >= COST_BUCKETS || variant >= nv {
            return None;
        }
        Some(bucket * nv + variant)
    }

    /// Record one normalized execution-cost observation (seconds per
    /// flop) for a `(feature bucket, variant)` cell; updates the cell's
    /// EWMA and observation count. Non-finite or non-positive costs and
    /// out-of-range indices are ignored (`false` return), never a panic.
    /// Two racing first observations may briefly under-seed the EWMA; it
    /// converges with the next few observations, which is all an
    /// exponentially-weighted estimate promises anyway.
    pub fn observe_cost_variant(&self, bucket: usize, variant: usize, cost: f64) -> bool {
        let Some(idx) = self.cost_cell(bucket, variant) else {
            return false;
        };
        if !cost.is_finite() || cost <= 0.0 {
            return false;
        }
        let seen = self.cost_obs[idx].fetch_add(1, Ordering::Relaxed);
        let cell = &self.cost_ewma[idx];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let old = f64::from_bits(cur);
            let next = if seen == 0 {
                cost
            } else {
                old + COST_EWMA_ALPHA * (cost - old)
            };
            match cell.compare_exchange_weak(
                cur,
                next.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(observed) => cur = observed,
            }
        }
        true
    }

    /// Family-grain cost observation — lands on the family's canonical
    /// SpMM variant cell.
    pub fn observe_cost(&self, bucket: usize, kernel: KernelKind, cost: f64) {
        self.observe_cost_variant(bucket, registry().canonical_id(SparseOp::Spmm, kernel), cost);
    }

    /// Current EWMA cost (seconds per flop) of a `(bucket, variant)`
    /// cell, or `None` if nothing was observed there yet (or either
    /// index is out of range).
    pub fn cost_variant(&self, bucket: usize, variant: usize) -> Option<f64> {
        let idx = self.cost_cell(bucket, variant)?;
        if self.cost_obs[idx].load(Ordering::Relaxed) == 0 {
            return None;
        }
        Some(f64::from_bits(self.cost_ewma[idx].load(Ordering::Relaxed)))
    }

    /// Observation count behind one `(bucket, variant)` cell (0 when
    /// either index is out of range).
    pub fn cost_observations_variant(&self, bucket: usize, variant: usize) -> u64 {
        self.cost_cell(bucket, variant)
            .map(|idx| self.cost_obs[idx].load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Family-grain cost view: the **best** (lowest) estimate among the
    /// family's SpMM variant cells with evidence — the number threshold
    /// refitting compares, since dispatch would pick that variant.
    pub fn cost(&self, bucket: usize, kernel: KernelKind) -> Option<f64> {
        registry()
            .family_variants(SparseOp::Spmm, kernel)
            .iter()
            .filter_map(|e| self.cost_variant(bucket, e.id))
            .reduce(f64::min)
    }

    /// Family-grain observation count: the sum over the family's SpMM
    /// variant cells.
    pub fn cost_observations(&self, bucket: usize, kernel: KernelKind) -> u64 {
        registry()
            .family_variants(SparseOp::Spmm, kernel)
            .iter()
            .map(|e| self.cost_observations_variant(bucket, e.id))
            .sum()
    }

    /// Forget every variant's EWMA and observation count for one feature
    /// bucket. Feature-drift handling calls this when a mutating matrix
    /// migrates across buckets: evidence gathered on the pre-drift shape
    /// would otherwise keep steering choices for content that no longer
    /// exists (the cold cells re-seed from the next observations). A
    /// racing `observe_cost_variant` may land between the two stores; the
    /// cell then re-seeds from that observation, which is the desired
    /// post-reset behavior anyway. Out-of-range buckets are a no-op.
    pub fn reset_cost_bucket(&self, bucket: usize) {
        if bucket >= COST_BUCKETS {
            return;
        }
        let nv = registry().len();
        for v in 0..nv {
            self.cost_obs[bucket * nv + v].store(0, Ordering::Relaxed);
            self.cost_ewma[bucket * nv + v].store(0, Ordering::Relaxed);
        }
    }

    /// Total cost observations across all cells.
    pub fn total_cost_observations(&self) -> u64 {
        self.cost_obs.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    fn grain_hists(&self, grain: Grain) -> &[AtomicHistogram] {
        match grain {
            Grain::Request => &self.request_hist,
            Grain::Shard => &self.shard_hist,
        }
    }

    /// Snapshot one op × grain × family latency distribution, merged
    /// across the family's variants.
    pub fn latency_histogram(
        &self,
        op: SparseOp,
        grain: Grain,
        kernel: KernelKind,
    ) -> HistogramSnapshot {
        let bank = self.grain_hists(grain);
        HistogramSnapshot::merged(
            registry()
                .family_variants(op, kernel)
                .iter()
                .map(|e| bank[e.id].snapshot()),
        )
    }

    /// Snapshot one grain × variant latency distribution (`None` for
    /// unknown ids).
    pub fn latency_histogram_variant(
        &self,
        grain: Grain,
        variant: usize,
    ) -> Option<HistogramSnapshot> {
        self.grain_hists(grain).get(variant).map(|h| h.snapshot())
    }

    /// Snapshot the latency distribution of one op × grain merged across
    /// all the op's variants.
    pub fn latency_histogram_merged(&self, op: SparseOp, grain: Grain) -> HistogramSnapshot {
        let bank = self.grain_hists(grain);
        HistogramSnapshot::merged(registry().op_variants(op).iter().map(|e| bank[e.id].snapshot()))
    }

    /// SpMM request-latency quantile across all kernels, from the
    /// lock-free histograms (bucket resolution: a √2 relative factor).
    pub fn latency_quantile(&self, q: f64) -> Duration {
        let snap = self.latency_histogram_merged(SparseOp::Spmm, Grain::Request);
        Duration::from_nanos(snap.quantile(q) as u64)
    }

    /// Record one native execution's analytic workload alongside its
    /// wallclock: the dispatch layer computes the
    /// [`WorkloadEstimate`] for the variant it ran and reports it here.
    /// Returns `false` (recording nothing) for an unknown variant id.
    pub fn record_workload(
        &self,
        variant: usize,
        est: &WorkloadEstimate,
        latency: Duration,
    ) -> bool {
        if variant >= self.wl_execs.len() {
            return false;
        }
        let ns = u64::try_from(latency.as_nanos()).unwrap_or(u64::MAX);
        self.wl_execs[variant].fetch_add(1, Ordering::Relaxed);
        self.wl_ns[variant].fetch_add(ns, Ordering::Relaxed);
        self.wl_flops[variant].fetch_add(est.flops, Ordering::Relaxed);
        self.wl_bytes_read[variant].fetch_add(est.bytes_read, Ordering::Relaxed);
        self.wl_bytes_written[variant].fetch_add(est.bytes_written, Ordering::Relaxed);
        self.wl_padding[variant].fetch_add(est.padding_bytes, Ordering::Relaxed);
        self.wl_rows[variant].fetch_add(est.rows, Ordering::Relaxed);
        self.wl_nnz[variant].fetch_add(est.nnz, Ordering::Relaxed);
        true
    }

    /// Accumulated workload totals of one variant id, or `None` when the
    /// id is unknown or the variant never executed — callers render only
    /// the live rows.
    pub fn workload_totals(&self, variant: usize) -> Option<WorkloadTotals> {
        let execs = self.wl_execs.get(variant)?.load(Ordering::Relaxed);
        if execs == 0 {
            return None;
        }
        Some(WorkloadTotals {
            execs,
            ns: self.wl_ns[variant].load(Ordering::Relaxed),
            flops: self.wl_flops[variant].load(Ordering::Relaxed),
            bytes_read: self.wl_bytes_read[variant].load(Ordering::Relaxed),
            bytes_written: self.wl_bytes_written[variant].load(Ordering::Relaxed),
            padding_bytes: self.wl_padding[variant].load(Ordering::Relaxed),
            rows: self.wl_rows[variant].load(Ordering::Relaxed),
            nnz: self.wl_nnz[variant].load(Ordering::Relaxed),
        })
    }

    /// Total flops accounted across every variant — the headline
    /// `ge_spmm_flops_total` counter.
    pub fn workload_flops_total(&self) -> u64 {
        self.wl_flops.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Record one sharded batch's nnz imbalance: the heaviest shard's
    /// nnz against the batch total over `shards` shards. Stored as an
    /// integer **milli-ratio** of max/mean
    /// (`max_nnz * 1000 * shards / total_nnz`, ≥ 1000 by construction —
    /// exactly 1000 means a perfectly balanced cut). Degenerate batches
    /// (no nnz, no shards) are ignored.
    pub fn record_shard_imbalance(&self, max_nnz: u64, total_nnz: u64, shards: u64) {
        if total_nnz == 0 || shards == 0 {
            return;
        }
        let milli = max_nnz.saturating_mul(1000).saturating_mul(shards) / total_nnz;
        self.imbalance_batches.fetch_add(1, Ordering::Relaxed);
        self.imbalance_milli_sum.fetch_add(milli, Ordering::Relaxed);
        self.imbalance_milli_max.fetch_max(milli, Ordering::Relaxed);
    }

    /// Sharded batches that reported an imbalance ratio.
    pub fn shard_imbalance_batches(&self) -> u64 {
        self.imbalance_batches.load(Ordering::Relaxed)
    }

    /// Mean per-batch max/mean nnz milli-ratio (0 when nothing was
    /// recorded; 1000 = perfectly balanced).
    pub fn shard_imbalance_mean_milli(&self) -> u64 {
        let n = self.shard_imbalance_batches();
        if n == 0 {
            return 0;
        }
        self.imbalance_milli_sum.load(Ordering::Relaxed) / n
    }

    /// Worst per-batch max/mean nnz milli-ratio seen.
    pub fn shard_imbalance_max_milli(&self) -> u64 {
        self.imbalance_milli_max.load(Ordering::Relaxed)
    }

    /// The flight recorder holding the last N request traces.
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// The selector decision audit log.
    pub fn audit(&self) -> &Arc<AuditLog> {
        &self.audit
    }

    /// The selector-regret tracker (folded into by the online selector).
    pub fn regret(&self) -> &Arc<RegretTracker> {
        &self.regret
    }

    /// Install the serving SLO monitor — called once by the serve path
    /// when `--slo` objectives were declared.
    pub fn install_slo(&self, monitor: Arc<SloMonitor>) {
        let mut slot = self.slo.lock().unwrap_or_else(|e| e.into_inner());
        *slot = Some(monitor);
    }

    /// The installed SLO monitor, if any.
    pub fn slo(&self) -> Option<Arc<SloMonitor>> {
        self.slo.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// One-line summary for logs. Shard, delta-reuse, cache and admission
    /// counters are appended only when their subsystem actually recorded
    /// something.
    pub fn summary(&self) -> String {
        let counts = self.kernel_counts();
        let mut out = format!(
            "requests={} errors={} mean={:?} p50={:?} p99={:?} kernels[sr_rs={} sr_wb={} pr_rs={} pr_wb={}]",
            self.requests(),
            self.errors(),
            self.mean_latency(),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
            counts[0],
            counts[1],
            counts[2],
            counts[3],
        );
        if self.shard_executions() > 0 {
            let sc = self.shard_kernel_counts();
            out.push_str(&format!(
                " shards[execs={} mean={:?} max={:?} sr_rs={} sr_wb={} pr_rs={} pr_wb={}]",
                self.shard_executions(),
                self.shard_mean_latency(),
                self.shard_max_latency(),
                sc[0],
                sc[1],
                sc[2],
                sc[3],
            ));
        }
        if self.shard_operands_reused() + self.shard_operands_reprepared() > 0 {
            out.push_str(&format!(
                " delta_shards[reused={} reprepared={}]",
                self.shard_operands_reused(),
                self.shard_operands_reprepared(),
            ));
        }
        if self.sddmm_requests() > 0 || self.sddmm_shard_executions() > 0 {
            let sc = self.sddmm_kernel_counts();
            let ssc = self.sddmm_shard_kernel_counts();
            out.push_str(&format!(
                " sddmm[requests={} mean={:?} sr_rs={} sr_wb={} pr_rs={} pr_wb={} \
                 shard_execs={} shard_sr_rs={} shard_sr_wb={} shard_pr_rs={} shard_pr_wb={}]",
                self.sddmm_requests(),
                self.sddmm_mean_latency(),
                sc[0],
                sc[1],
                sc[2],
                sc[3],
                self.sddmm_shard_executions(),
                ssc[0],
                ssc[1],
                ssc[2],
                ssc[3],
            ));
        }
        if self.cache_hits() + self.cache_misses() > 0 {
            out.push_str(&format!(
                " cache[hits={} misses={} evictions={}]",
                self.cache_hits(),
                self.cache_misses(),
                self.cache_evictions(),
            ));
        }
        if self.rejections() > 0 || self.max_queue_depth() > 0 {
            out.push_str(&format!(
                " queue[max_depth={} rejected={}]",
                self.max_queue_depth(),
                self.rejections(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::default();
        m.record(KernelKind::PrWb, Duration::from_micros(100));
        m.record(KernelKind::PrWb, Duration::from_micros(300));
        m.record(KernelKind::SrRs, Duration::from_micros(200));
        m.record_error();
        assert_eq!(m.requests(), 3);
        assert_eq!(m.errors(), 1);
        assert_eq!(m.kernel_counts(), [1, 0, 0, 2]);
        assert_eq!(m.mean_latency(), Duration::from_micros(200));
        assert!(m.latency_quantile(0.99) >= m.latency_quantile(0.5));
        assert!(m.summary().contains("requests=3"));
    }

    #[test]
    fn shard_counters_are_separate_from_requests() {
        let m = Metrics::default();
        assert_eq!(m.shard_executions(), 0);
        assert!(!m.summary().contains("shards["));
        m.record(KernelKind::SrRs, Duration::from_micros(500));
        m.record_shard(KernelKind::SrWb, Duration::from_micros(100));
        m.record_shard(KernelKind::PrWb, Duration::from_micros(300));
        assert_eq!(m.requests(), 1);
        assert_eq!(m.shard_executions(), 2);
        assert_eq!(m.shard_kernel_counts(), [0, 1, 0, 1]);
        assert_eq!(m.shard_mean_latency(), Duration::from_micros(200));
        assert_eq!(m.shard_max_latency(), Duration::from_micros(300));
        let s = m.summary();
        assert!(s.contains("shards[execs=2"), "{s}");
    }

    #[test]
    fn sddmm_counters_are_tagged_apart_from_spmm() {
        let m = Metrics::default();
        assert_eq!(m.sddmm_requests(), 0);
        assert!(!m.summary().contains("sddmm["));
        m.record(KernelKind::SrRs, Duration::from_micros(100));
        m.record_sddmm(KernelKind::PrWb, Duration::from_micros(200));
        m.record_sddmm(KernelKind::PrWb, Duration::from_micros(400));
        m.record_sddmm_shard(KernelKind::SrWb, Duration::from_micros(50));
        m.record_sddmm_shard(KernelKind::PrRs, Duration::from_micros(150));
        // per-op request counters stay separate
        assert_eq!(m.requests(), 1);
        assert_eq!(m.sddmm_requests(), 2);
        assert_eq!(m.kernel_counts(), [1, 0, 0, 0]);
        assert_eq!(m.sddmm_kernel_counts(), [0, 0, 0, 2]);
        assert_eq!(m.sddmm_mean_latency(), Duration::from_micros(300));
        // shard grain too
        assert_eq!(m.shard_executions(), 0);
        assert_eq!(m.sddmm_shard_executions(), 2);
        assert_eq!(m.sddmm_shard_kernel_counts(), [0, 1, 1, 0]);
        assert_eq!(m.sddmm_shard_mean_latency(), Duration::from_micros(100));
        let s = m.summary();
        assert!(s.contains("sddmm[requests=2"), "{s}");
    }

    #[test]
    fn histograms_are_banked_per_op_grain_and_kernel() {
        let m = Metrics::default();
        m.record(KernelKind::SrRs, Duration::from_micros(100));
        m.record_shard(KernelKind::SrWb, Duration::from_micros(20));
        m.record_sddmm(KernelKind::PrRs, Duration::from_micros(400));
        m.record_sddmm_shard(KernelKind::PrWb, Duration::from_micros(30));
        let cases = [
            (SparseOp::Spmm, Grain::Request, KernelKind::SrRs, 100_000u64),
            (SparseOp::Spmm, Grain::Shard, KernelKind::SrWb, 20_000),
            (SparseOp::Sddmm, Grain::Request, KernelKind::PrRs, 400_000),
            (SparseOp::Sddmm, Grain::Shard, KernelKind::PrWb, 30_000),
        ];
        for (op, grain, kernel, ns) in cases {
            let snap = m.latency_histogram(op, grain, kernel);
            assert_eq!(snap.count, 1, "{op:?}/{grain:?}/{kernel:?}");
            assert_eq!(snap.sum, ns);
            // every other kernel's histogram in the same bank is empty
            for other in KernelKind::ALL {
                if other != kernel {
                    assert!(m.latency_histogram(op, grain, other).is_empty());
                }
            }
            let merged = m.latency_histogram_merged(op, grain);
            assert_eq!(merged.count, 1);
            assert_eq!(merged.max, ns);
        }
    }

    #[test]
    fn variant_grain_banks_aggregate_into_family_views() {
        let m = Metrics::default();
        let reg = registry();
        let canon = reg.canonical_id(SparseOp::Spmm, KernelKind::SrRs);
        let tiled = reg.by_label(SparseOp::Spmm, "sr_rs.t4").unwrap().id;
        assert!(m.record_request_variant(canon, Duration::from_micros(10)));
        assert!(m.record_request_variant(tiled, Duration::from_micros(20)));
        assert!(m.record_shard_variant(tiled, Duration::from_micros(5)));
        // family views sum the variants
        assert_eq!(m.requests(), 2);
        assert_eq!(m.kernel_counts(), [2, 0, 0, 0]);
        assert_eq!(m.shard_kernel_counts(), [1, 0, 0, 0]);
        // variant resolution underneath
        assert_eq!(m.variant_request_count(canon), 1);
        assert_eq!(m.variant_request_count(tiled), 1);
        assert_eq!(m.variant_shard_count(tiled), 1);
        let snap = m.latency_histogram_variant(Grain::Request, tiled).unwrap();
        assert_eq!(snap.count, 1);
        let fam = m.latency_histogram(SparseOp::Spmm, Grain::Request, KernelKind::SrRs);
        assert_eq!(fam.count, 2, "family histogram merges variants");
        // unknown ids record nothing and read as empty
        assert!(!m.record_request_variant(usize::MAX, Duration::from_micros(1)));
        assert!(!m.record_shard_variant(usize::MAX, Duration::from_micros(1)));
        assert_eq!(m.variant_request_count(usize::MAX), 0);
        assert!(m.latency_histogram_variant(Grain::Request, usize::MAX).is_none());
        assert_eq!(m.requests(), 2);
    }

    #[test]
    fn cache_and_admission_counters_are_opt_in_sections() {
        let m = Metrics::default();
        let base = m.summary();
        assert!(!base.contains("cache["), "{base}");
        assert!(!base.contains("queue["), "{base}");
        m.record_cache_miss();
        m.record_cache_hit();
        m.record_cache_hit();
        m.record_cache_evictions(0); // no-op
        m.record_cache_evictions(3);
        assert_eq!(m.cache_hits(), 2);
        assert_eq!(m.cache_misses(), 1);
        assert_eq!(m.cache_evictions(), 3);
        m.record_queue_depth(4);
        m.record_queue_depth(9);
        m.record_queue_depth(2);
        m.record_rejection();
        assert_eq!(m.max_queue_depth(), 9);
        assert_eq!(m.rejections(), 1);
        let s = m.summary();
        assert!(s.contains("cache[hits=2 misses=1 evictions=3]"), "{s}");
        assert!(s.contains("queue[max_depth=9 rejected=1]"), "{s}");
    }

    #[test]
    fn cost_ewma_tracks_observations() {
        let m = Metrics::default();
        assert_eq!(m.cost(0, KernelKind::SrRs), None);
        assert_eq!(m.total_cost_observations(), 0);
        m.observe_cost(0, KernelKind::SrRs, 1.0);
        assert_eq!(m.cost(0, KernelKind::SrRs), Some(1.0), "first seeds");
        m.observe_cost(0, KernelKind::SrRs, 2.0);
        let blended = m.cost(0, KernelKind::SrRs).unwrap();
        assert!((blended - (1.0 + COST_EWMA_ALPHA)).abs() < 1e-12, "{blended}");
        assert_eq!(m.cost_observations(0, KernelKind::SrRs), 2);
        // cells are independent
        assert_eq!(m.cost(0, KernelKind::PrWb), None);
        assert_eq!(m.cost(COST_BUCKETS - 1, KernelKind::SrRs), None);
        // garbage observations are dropped
        m.observe_cost(1, KernelKind::PrRs, f64::NAN);
        m.observe_cost(1, KernelKind::PrRs, 0.0);
        m.observe_cost(1, KernelKind::PrRs, -1.0);
        assert_eq!(m.cost(1, KernelKind::PrRs), None);
        assert_eq!(m.total_cost_observations(), 2);
    }

    #[test]
    fn variant_cost_cells_feed_the_family_view() {
        let m = Metrics::default();
        let reg = registry();
        let canon = reg.canonical_id(SparseOp::Spmm, KernelKind::SrRs);
        let tiled = reg.by_label(SparseOp::Spmm, "sr_rs.t1").unwrap().id;
        assert!(m.observe_cost_variant(2, canon, 4.0));
        assert!(m.observe_cost_variant(2, tiled, 1.0));
        assert_eq!(m.cost_variant(2, canon), Some(4.0));
        assert_eq!(m.cost_variant(2, tiled), Some(1.0));
        // the family view reports the best variant's estimate
        assert_eq!(m.cost(2, KernelKind::SrRs), Some(1.0));
        assert_eq!(m.cost_observations(2, KernelKind::SrRs), 2);
        assert_eq!(m.cost_observations_variant(2, tiled), 1);
        // out-of-range indices are rejected, not panics
        assert!(!m.observe_cost_variant(COST_BUCKETS, canon, 1.0));
        assert!(!m.observe_cost_variant(0, usize::MAX, 1.0));
        assert_eq!(m.cost_variant(COST_BUCKETS, canon), None);
        assert_eq!(m.cost_observations_variant(0, usize::MAX), 0);
        m.reset_cost_bucket(COST_BUCKETS); // out of range: no-op, no panic
        m.reset_cost_bucket(2);
        assert_eq!(m.cost(2, KernelKind::SrRs), None);
    }

    #[test]
    fn reset_cost_bucket_clears_one_bucket_only() {
        let m = Metrics::default();
        m.observe_cost(2, KernelKind::SrRs, 1.0);
        m.observe_cost(2, KernelKind::PrWb, 3.0);
        m.observe_cost(5, KernelKind::SrRs, 7.0);
        m.reset_cost_bucket(2);
        assert_eq!(m.cost(2, KernelKind::SrRs), None);
        assert_eq!(m.cost(2, KernelKind::PrWb), None);
        assert_eq!(m.cost_observations(2, KernelKind::SrRs), 0);
        // other buckets keep their evidence
        assert_eq!(m.cost(5, KernelKind::SrRs), Some(7.0));
        assert_eq!(m.total_cost_observations(), 1);
        // the cleared cell re-seeds from the next observation
        m.observe_cost(2, KernelKind::SrRs, 4.0);
        assert_eq!(m.cost(2, KernelKind::SrRs), Some(4.0));
    }

    #[test]
    fn cost_ewma_concurrent_observers_converge() {
        let m = std::sync::Arc::new(Metrics::default());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..500 {
                        m.observe_cost(3, KernelKind::SrWb, 2.0);
                    }
                });
            }
        });
        assert_eq!(m.cost_observations(3, KernelKind::SrWb), 2000);
        let c = m.cost(3, KernelKind::SrWb).unwrap();
        assert!((c - 2.0).abs() < 1e-6, "constant stream converges: {c}");
    }

    #[test]
    fn shard_reuse_counters_accumulate() {
        let m = Metrics::default();
        assert_eq!(m.shard_operands_reused(), 0);
        assert!(!m.summary().contains("delta_shards["));
        m.record_shard_reuse(3, 1);
        m.record_shard_reuse(0, 0); // no-op
        m.record_shard_reuse(1, 2);
        assert_eq!(m.shard_operands_reused(), 4);
        assert_eq!(m.shard_operands_reprepared(), 3);
        assert!(m.summary().contains("delta_shards[reused=4 reprepared=3]"));
    }

    #[test]
    fn workload_banks_accumulate_per_variant() {
        let m = Metrics::default();
        assert_eq!(m.workload_totals(0), None, "no executions yet");
        assert_eq!(m.workload_totals(usize::MAX), None, "unknown id");
        let est = WorkloadEstimate {
            flops: 160,
            bytes_read: 420,
            bytes_written: 128,
            padding_bytes: 0,
            rows: 4,
            nnz: 10,
        };
        assert!(m.record_workload(0, &est, Duration::from_nanos(80)));
        assert!(m.record_workload(0, &est, Duration::from_nanos(80)));
        assert!(!m.record_workload(usize::MAX, &est, Duration::from_nanos(1)));
        let t = m.workload_totals(0).unwrap();
        assert_eq!(t.execs, 2);
        assert_eq!(t.ns, 160);
        assert_eq!(t.flops, 320);
        assert_eq!(t.bytes_read, 840);
        assert_eq!(t.bytes_written, 256);
        assert_eq!(t.rows, 8);
        assert_eq!(t.nnz, 20);
        assert_eq!(m.workload_flops_total(), 320);
        // 320 flops over 160 ns = 2 GFLOP/s exactly
        assert!((t.achieved_gflops() - 2.0).abs() < 1e-12);
        assert_eq!(m.workload_totals(1), None, "other variants untouched");
    }

    #[test]
    fn shard_imbalance_tracks_mean_and_max_milli_ratio() {
        let m = Metrics::default();
        assert_eq!(m.shard_imbalance_batches(), 0);
        assert_eq!(m.shard_imbalance_mean_milli(), 0);
        // perfectly balanced: 4 shards, max 25 of 100 → 1000
        m.record_shard_imbalance(25, 100, 4);
        // skewed: max 60 of 100 over 4 shards → 2400
        m.record_shard_imbalance(60, 100, 4);
        m.record_shard_imbalance(5, 0, 4); // degenerate: ignored
        m.record_shard_imbalance(5, 10, 0); // degenerate: ignored
        assert_eq!(m.shard_imbalance_batches(), 2);
        assert_eq!(m.shard_imbalance_mean_milli(), 1700);
        assert_eq!(m.shard_imbalance_max_milli(), 2400);
    }

    #[test]
    fn regret_tracker_and_slo_monitor_hang_off_the_hub() {
        let m = Metrics::default();
        assert_eq!(m.regret().folds(), 0);
        m.regret().fold(SparseOp::Spmm, 0, 0, 2.0e-12, 1.0e-12);
        assert_eq!(m.regret().folds(), 1);
        assert!(m.slo().is_none(), "no monitor until serve installs one");
        let spec = crate::obs::slo::SloSpec::parse("p99=1ms").unwrap();
        m.install_slo(std::sync::Arc::new(SloMonitor::new(spec)));
        let slo = m.slo().expect("installed");
        slo.observe(Duration::from_micros(10), 0);
        assert_eq!(slo.observed(), 1);
    }

    #[test]
    fn trace_capacity_is_configurable() {
        let m = Metrics::with_trace_capacity(2);
        assert_eq!(m.recorder().capacity(), 2);
        assert_eq!(Metrics::default().recorder().capacity(), 64);
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(Metrics::default());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.record(KernelKind::SrWb, Duration::from_nanos(10));
                    }
                });
            }
        });
        assert_eq!(m.requests(), 8000);
        assert_eq!(m.kernel_counts()[1], 8000);
        let snap = m.latency_histogram(SparseOp::Spmm, Grain::Request, KernelKind::SrWb);
        assert_eq!(snap.count, 8000);
        assert_eq!(snap.sum, 80_000);
        assert_eq!(snap.counts.iter().sum::<u64>(), 8000);
    }
}
