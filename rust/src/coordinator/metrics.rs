//! Coordinator metrics: request counts, per-kernel selection counts,
//! latency histograms, and the observability hub. Lock-free on the hot
//! path — every counter is a relaxed atomic and every latency
//! distribution is a log-bucketed [`AtomicHistogram`]; the only mutexes
//! are inside the bounded flight-recorder and audit rings (one short,
//! poison-tolerant acquisition per request), so a panicking worker can
//! never wedge stats for the whole server.
//!
//! Requests and shards are counted separately: one sharded request fans
//! out into K shard executions, each with its own kernel choice and
//! wallclock. The `shard_*` counters are how per-shard adaptivity is
//! observed from outside (`crate::shard::ShardedBackend` records them).
//!
//! The two sparse ops are **tagged apart**: `record`/`record_shard`
//! count SpMM, `record_sddmm`/`record_sddmm_shard` count SDDMM, so
//! per-op kernel selection stays observable when traffic mixes the
//! FusedMM pair (attention workloads — `DESIGN.md` §SDDMM). Latency
//! quantiles come per **op × grain × kernel** from the histogram banks
//! ([`Metrics::latency_histogram`]); the exposition surface
//! (`crate::obs::expo`) renders them as Prometheus text and JSON.
//!
//! `Metrics` is also the hub the rest of the observability subsystem
//! hangs off: the request-trace [`FlightRecorder`] and the selector
//! decision [`AuditLog`] live here because every layer that needs them
//! (engine, server, batcher, sharded backend) already shares one
//! `Arc<Metrics>`.
//!
//! The per-`(feature bucket, kernel)` cost EWMAs ([`Metrics::observe_cost`]
//! / [`Metrics::cost`]) are the substrate of online selector refinement:
//! executions report normalized latencies here, and
//! [`crate::selector::OnlineSelector`] refits its thresholds against the
//! table (`DESIGN.md` §Measured calibration).

use crate::kernels::{KernelKind, SparseOp};
use crate::obs::audit::AuditLog;
use crate::obs::hist::{AtomicHistogram, HistogramSnapshot};
use crate::obs::trace::FlightRecorder;
use crate::obs::Grain;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Number of feature buckets the per-kernel cost EWMAs are keyed by.
/// The bucketing function lives in [`crate::selector::online`]
/// (`feature_bucket`); `Metrics` only stores the table.
pub const COST_BUCKETS: usize = 12;

/// EWMA smoothing factor for the cost table: each observation moves the
/// estimate 25% toward itself — reactive enough for online refinement,
/// damped enough to ride out scheduler noise.
pub const COST_EWMA_ALPHA: f64 = 0.25;

/// Aggregate metrics for an engine instance.
#[derive(Debug, Default)]
pub struct Metrics {
    requests: AtomicU64,
    errors: AtomicU64,
    by_kernel: [AtomicU64; 4],
    /// total execution nanoseconds
    exec_ns: AtomicU64,
    /// per-kernel request-latency histograms, [`KernelKind::ALL`] order
    request_hist: [AtomicHistogram; 4],
    /// shard-level counters (sharded backends only; zero otherwise)
    shard_execs: AtomicU64,
    shard_by_kernel: [AtomicU64; 4],
    shard_ns: AtomicU64,
    /// slowest single shard execution seen — the fan-out straggler bound
    shard_max_ns: AtomicU64,
    shard_hist: [AtomicHistogram; 4],
    /// SDDMM request-level counters — the second sparse op is tagged
    /// apart from SpMM so per-op kernel selection stays observable
    sddmm_requests: AtomicU64,
    sddmm_by_kernel: [AtomicU64; 4],
    sddmm_ns: AtomicU64,
    sddmm_request_hist: [AtomicHistogram; 4],
    /// SDDMM shard-level counters (sharded backends only)
    sddmm_shard_execs: AtomicU64,
    sddmm_shard_by_kernel: [AtomicU64; 4],
    sddmm_shard_ns: AtomicU64,
    sddmm_shard_hist: [AtomicHistogram; 4],
    /// prepared-matrix cache counters (engines with a cache only)
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_evictions: AtomicU64,
    /// serving-layer admission counters (multi-worker `Server` only)
    rejected: AtomicU64,
    /// high-water mark of in-flight requests observed at admission
    queue_depth_max: AtomicU64,
    /// per-(feature-bucket, kernel) EWMA of normalized execution cost
    /// (seconds per flop), stored as f64 bits; what the online selector
    /// refits thresholds against
    cost_ewma: [[AtomicU64; 4]; COST_BUCKETS],
    /// observation counts behind each EWMA cell (0 = cell is empty)
    cost_obs: [[AtomicU64; 4]; COST_BUCKETS],
    /// ring of the last N request traces (committed at request end)
    recorder: Arc<FlightRecorder>,
    /// ring of recent selector decisions with features and thresholds
    audit: Arc<AuditLog>,
}

fn kidx(kernel: KernelKind) -> usize {
    KernelKind::ALL.iter().position(|k| *k == kernel).unwrap()
}

impl Metrics {
    /// Record one completed request.
    pub fn record(&self, kernel: KernelKind, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let idx = kidx(kernel);
        self.by_kernel[idx].fetch_add(1, Ordering::Relaxed);
        self.exec_ns
            .fetch_add(latency.as_nanos() as u64, Ordering::Relaxed);
        self.request_hist[idx].record_duration(latency);
    }

    /// Record a failed request.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one shard execution inside a sharded request. `kernel` is
    /// the shard's own choice, which in adaptive mode may differ from the
    /// request-level kernel recorded by [`Metrics::record`].
    pub fn record_shard(&self, kernel: KernelKind, latency: Duration) {
        self.shard_execs.fetch_add(1, Ordering::Relaxed);
        let idx = kidx(kernel);
        self.shard_by_kernel[idx].fetch_add(1, Ordering::Relaxed);
        let ns = latency.as_nanos() as u64;
        self.shard_ns.fetch_add(ns, Ordering::Relaxed);
        self.shard_max_ns.fetch_max(ns, Ordering::Relaxed);
        self.shard_hist[idx].record_duration(latency);
    }

    /// Completed request count.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Error count.
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Requests per kernel, in [`KernelKind::ALL`] order.
    pub fn kernel_counts(&self) -> [u64; 4] {
        [
            self.by_kernel[0].load(Ordering::Relaxed),
            self.by_kernel[1].load(Ordering::Relaxed),
            self.by_kernel[2].load(Ordering::Relaxed),
            self.by_kernel[3].load(Ordering::Relaxed),
        ]
    }

    /// Mean execution latency.
    pub fn mean_latency(&self) -> Duration {
        let n = self.requests();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.exec_ns.load(Ordering::Relaxed) / n)
    }

    /// Shard executions recorded (0 unless a sharded backend is in use).
    pub fn shard_executions(&self) -> u64 {
        self.shard_execs.load(Ordering::Relaxed)
    }

    /// Shard executions per kernel, in [`KernelKind::ALL`] order — the
    /// observable trace of per-shard adaptive choices.
    pub fn shard_kernel_counts(&self) -> [u64; 4] {
        [
            self.shard_by_kernel[0].load(Ordering::Relaxed),
            self.shard_by_kernel[1].load(Ordering::Relaxed),
            self.shard_by_kernel[2].load(Ordering::Relaxed),
            self.shard_by_kernel[3].load(Ordering::Relaxed),
        ]
    }

    /// Mean single-shard execution latency.
    pub fn shard_mean_latency(&self) -> Duration {
        let n = self.shard_executions();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.shard_ns.load(Ordering::Relaxed) / n)
    }

    /// Slowest single-shard execution — the straggler that bounds fan-out
    /// wallclock.
    pub fn shard_max_latency(&self) -> Duration {
        Duration::from_nanos(self.shard_max_ns.load(Ordering::Relaxed))
    }

    /// Record one completed SDDMM request. Op-tagged apart from
    /// [`Metrics::record`] so SpMM and SDDMM kernel selection are
    /// observable per op.
    pub fn record_sddmm(&self, kernel: KernelKind, latency: Duration) {
        self.sddmm_requests.fetch_add(1, Ordering::Relaxed);
        let idx = kidx(kernel);
        self.sddmm_by_kernel[idx].fetch_add(1, Ordering::Relaxed);
        self.sddmm_ns
            .fetch_add(latency.as_nanos() as u64, Ordering::Relaxed);
        self.sddmm_request_hist[idx].record_duration(latency);
    }

    /// Record one SDDMM shard execution inside a sharded request.
    pub fn record_sddmm_shard(&self, kernel: KernelKind, latency: Duration) {
        self.sddmm_shard_execs.fetch_add(1, Ordering::Relaxed);
        let idx = kidx(kernel);
        self.sddmm_shard_by_kernel[idx].fetch_add(1, Ordering::Relaxed);
        self.sddmm_shard_ns
            .fetch_add(latency.as_nanos() as u64, Ordering::Relaxed);
        self.sddmm_shard_hist[idx].record_duration(latency);
    }

    /// Completed SDDMM request count.
    pub fn sddmm_requests(&self) -> u64 {
        self.sddmm_requests.load(Ordering::Relaxed)
    }

    /// SDDMM requests per kernel, in [`KernelKind::ALL`] order — the
    /// per-op selection counter the serving layer exposes.
    pub fn sddmm_kernel_counts(&self) -> [u64; 4] {
        [
            self.sddmm_by_kernel[0].load(Ordering::Relaxed),
            self.sddmm_by_kernel[1].load(Ordering::Relaxed),
            self.sddmm_by_kernel[2].load(Ordering::Relaxed),
            self.sddmm_by_kernel[3].load(Ordering::Relaxed),
        ]
    }

    /// Mean SDDMM execution latency.
    pub fn sddmm_mean_latency(&self) -> Duration {
        let n = self.sddmm_requests();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sddmm_ns.load(Ordering::Relaxed) / n)
    }

    /// SDDMM shard executions recorded (0 unless a sharded backend ran
    /// the op).
    pub fn sddmm_shard_executions(&self) -> u64 {
        self.sddmm_shard_execs.load(Ordering::Relaxed)
    }

    /// SDDMM shard executions per kernel, in [`KernelKind::ALL`] order —
    /// the observable trace of per-shard adaptive SDDMM choices.
    pub fn sddmm_shard_kernel_counts(&self) -> [u64; 4] {
        [
            self.sddmm_shard_by_kernel[0].load(Ordering::Relaxed),
            self.sddmm_shard_by_kernel[1].load(Ordering::Relaxed),
            self.sddmm_shard_by_kernel[2].load(Ordering::Relaxed),
            self.sddmm_shard_by_kernel[3].load(Ordering::Relaxed),
        ]
    }

    /// Mean single-shard SDDMM execution latency.
    pub fn sddmm_shard_mean_latency(&self) -> Duration {
        let n = self.sddmm_shard_executions();
        if n == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sddmm_shard_ns.load(Ordering::Relaxed) / n)
    }

    /// Record a prepared-matrix cache hit (registration skipped prepare).
    pub fn record_cache_hit(&self) {
        self.cache_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a prepared-matrix cache miss (registration paid prepare).
    pub fn record_cache_miss(&self) {
        self.cache_misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` cache evictions caused by one insertion.
    pub fn record_cache_evictions(&self, n: u64) {
        if n > 0 {
            self.cache_evictions.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Record a request refused at admission (server at capacity).
    pub fn record_rejection(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record the in-flight request count observed at one admission;
    /// keeps the high-water mark.
    pub fn record_queue_depth(&self, depth: usize) {
        self.queue_depth_max.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Prepared-matrix cache hits (registrations that skipped prepare).
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Prepared-matrix cache misses (registrations that paid prepare).
    pub fn cache_misses(&self) -> u64 {
        self.cache_misses.load(Ordering::Relaxed)
    }

    /// Entries evicted from the prepared-matrix cache so far.
    pub fn cache_evictions(&self) -> u64 {
        self.cache_evictions.load(Ordering::Relaxed)
    }

    /// Requests refused at admission (server at capacity).
    pub fn rejections(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// High-water mark of in-flight requests observed at admission.
    pub fn max_queue_depth(&self) -> u64 {
        self.queue_depth_max.load(Ordering::Relaxed)
    }

    /// Record one normalized execution-cost observation (seconds per
    /// flop) for a `(feature bucket, kernel)` cell; updates the cell's
    /// EWMA and observation count. Non-finite or non-positive costs are
    /// ignored. Two racing first observations may briefly under-seed the
    /// EWMA; it converges with the next few observations, which is all an
    /// exponentially-weighted estimate promises anyway.
    pub fn observe_cost(&self, bucket: usize, kernel: KernelKind, cost: f64) {
        assert!(bucket < COST_BUCKETS, "bucket {bucket} out of range");
        if !cost.is_finite() || cost <= 0.0 {
            return;
        }
        let k = kidx(kernel);
        let seen = self.cost_obs[bucket][k].fetch_add(1, Ordering::Relaxed);
        let cell = &self.cost_ewma[bucket][k];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let old = f64::from_bits(cur);
            let next = if seen == 0 {
                cost
            } else {
                old + COST_EWMA_ALPHA * (cost - old)
            };
            match cell.compare_exchange_weak(
                cur,
                next.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(observed) => cur = observed,
            }
        }
    }

    /// Current EWMA cost (seconds per flop) of a `(bucket, kernel)` cell,
    /// or `None` if nothing was observed there yet.
    pub fn cost(&self, bucket: usize, kernel: KernelKind) -> Option<f64> {
        let k = kidx(kernel);
        if self.cost_obs[bucket][k].load(Ordering::Relaxed) == 0 {
            return None;
        }
        Some(f64::from_bits(self.cost_ewma[bucket][k].load(Ordering::Relaxed)))
    }

    /// Observation count behind one `(bucket, kernel)` EWMA cell.
    pub fn cost_observations(&self, bucket: usize, kernel: KernelKind) -> u64 {
        self.cost_obs[bucket][kidx(kernel)].load(Ordering::Relaxed)
    }

    /// Forget every kernel's EWMA and observation count for one feature
    /// bucket. Feature-drift handling calls this when a mutating matrix
    /// migrates across buckets: evidence gathered on the pre-drift shape
    /// would otherwise keep steering choices for content that no longer
    /// exists (the cold cells re-seed from the next observations). A
    /// racing `observe_cost` may land between the two stores; the cell
    /// then re-seeds from that observation, which is the desired
    /// post-reset behavior anyway.
    pub fn reset_cost_bucket(&self, bucket: usize) {
        assert!(bucket < COST_BUCKETS, "bucket {bucket} out of range");
        for k in 0..4 {
            self.cost_obs[bucket][k].store(0, Ordering::Relaxed);
            self.cost_ewma[bucket][k].store(0, Ordering::Relaxed);
        }
    }

    /// Total cost observations across all cells.
    pub fn total_cost_observations(&self) -> u64 {
        self.cost_obs
            .iter()
            .flat_map(|row| row.iter())
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    }

    fn hist_bank(&self, op: SparseOp, grain: Grain) -> &[AtomicHistogram; 4] {
        match (op, grain) {
            (SparseOp::Spmm, Grain::Request) => &self.request_hist,
            (SparseOp::Spmm, Grain::Shard) => &self.shard_hist,
            (SparseOp::Sddmm, Grain::Request) => &self.sddmm_request_hist,
            (SparseOp::Sddmm, Grain::Shard) => &self.sddmm_shard_hist,
        }
    }

    /// Snapshot one op × grain × kernel latency histogram.
    pub fn latency_histogram(
        &self,
        op: SparseOp,
        grain: Grain,
        kernel: KernelKind,
    ) -> HistogramSnapshot {
        self.hist_bank(op, grain)[kidx(kernel)].snapshot()
    }

    /// Snapshot the latency distribution of one op × grain merged across
    /// all four kernels.
    pub fn latency_histogram_merged(&self, op: SparseOp, grain: Grain) -> HistogramSnapshot {
        HistogramSnapshot::merged(self.hist_bank(op, grain).iter().map(|h| h.snapshot()))
    }

    /// SpMM request-latency quantile across all kernels, from the
    /// lock-free histograms (bucket resolution: a √2 relative factor).
    pub fn latency_quantile(&self, q: f64) -> Duration {
        let snap = self.latency_histogram_merged(SparseOp::Spmm, Grain::Request);
        Duration::from_nanos(snap.quantile(q) as u64)
    }

    /// The flight recorder holding the last N request traces.
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// The selector decision audit log.
    pub fn audit(&self) -> &Arc<AuditLog> {
        &self.audit
    }

    /// One-line summary for logs. Shard, cache and admission counters are
    /// appended only when their subsystem actually recorded something.
    pub fn summary(&self) -> String {
        let counts = self.kernel_counts();
        let mut out = format!(
            "requests={} errors={} mean={:?} p50={:?} p99={:?} kernels[sr_rs={} sr_wb={} pr_rs={} pr_wb={}]",
            self.requests(),
            self.errors(),
            self.mean_latency(),
            self.latency_quantile(0.5),
            self.latency_quantile(0.99),
            counts[0],
            counts[1],
            counts[2],
            counts[3],
        );
        if self.shard_executions() > 0 {
            let sc = self.shard_kernel_counts();
            out.push_str(&format!(
                " shards[execs={} mean={:?} max={:?} sr_rs={} sr_wb={} pr_rs={} pr_wb={}]",
                self.shard_executions(),
                self.shard_mean_latency(),
                self.shard_max_latency(),
                sc[0],
                sc[1],
                sc[2],
                sc[3],
            ));
        }
        if self.sddmm_requests() > 0 || self.sddmm_shard_executions() > 0 {
            let sc = self.sddmm_kernel_counts();
            let ssc = self.sddmm_shard_kernel_counts();
            out.push_str(&format!(
                " sddmm[requests={} mean={:?} sr_rs={} sr_wb={} pr_rs={} pr_wb={} \
                 shard_execs={} shard_sr_rs={} shard_sr_wb={} shard_pr_rs={} shard_pr_wb={}]",
                self.sddmm_requests(),
                self.sddmm_mean_latency(),
                sc[0],
                sc[1],
                sc[2],
                sc[3],
                self.sddmm_shard_executions(),
                ssc[0],
                ssc[1],
                ssc[2],
                ssc[3],
            ));
        }
        if self.cache_hits() + self.cache_misses() > 0 {
            out.push_str(&format!(
                " cache[hits={} misses={} evictions={}]",
                self.cache_hits(),
                self.cache_misses(),
                self.cache_evictions(),
            ));
        }
        if self.rejections() > 0 || self.max_queue_depth() > 0 {
            out.push_str(&format!(
                " queue[max_depth={} rejected={}]",
                self.max_queue_depth(),
                self.rejections(),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = Metrics::default();
        m.record(KernelKind::PrWb, Duration::from_micros(100));
        m.record(KernelKind::PrWb, Duration::from_micros(300));
        m.record(KernelKind::SrRs, Duration::from_micros(200));
        m.record_error();
        assert_eq!(m.requests(), 3);
        assert_eq!(m.errors(), 1);
        assert_eq!(m.kernel_counts(), [1, 0, 0, 2]);
        assert_eq!(m.mean_latency(), Duration::from_micros(200));
        assert!(m.latency_quantile(0.99) >= m.latency_quantile(0.5));
        assert!(m.summary().contains("requests=3"));
    }

    #[test]
    fn shard_counters_are_separate_from_requests() {
        let m = Metrics::default();
        assert_eq!(m.shard_executions(), 0);
        assert!(!m.summary().contains("shards["));
        m.record(KernelKind::SrRs, Duration::from_micros(500));
        m.record_shard(KernelKind::SrWb, Duration::from_micros(100));
        m.record_shard(KernelKind::PrWb, Duration::from_micros(300));
        assert_eq!(m.requests(), 1);
        assert_eq!(m.shard_executions(), 2);
        assert_eq!(m.shard_kernel_counts(), [0, 1, 0, 1]);
        assert_eq!(m.shard_mean_latency(), Duration::from_micros(200));
        assert_eq!(m.shard_max_latency(), Duration::from_micros(300));
        let s = m.summary();
        assert!(s.contains("shards[execs=2"), "{s}");
    }

    #[test]
    fn sddmm_counters_are_tagged_apart_from_spmm() {
        let m = Metrics::default();
        assert_eq!(m.sddmm_requests(), 0);
        assert!(!m.summary().contains("sddmm["));
        m.record(KernelKind::SrRs, Duration::from_micros(100));
        m.record_sddmm(KernelKind::PrWb, Duration::from_micros(200));
        m.record_sddmm(KernelKind::PrWb, Duration::from_micros(400));
        m.record_sddmm_shard(KernelKind::SrWb, Duration::from_micros(50));
        m.record_sddmm_shard(KernelKind::PrRs, Duration::from_micros(150));
        // per-op request counters stay separate
        assert_eq!(m.requests(), 1);
        assert_eq!(m.sddmm_requests(), 2);
        assert_eq!(m.kernel_counts(), [1, 0, 0, 0]);
        assert_eq!(m.sddmm_kernel_counts(), [0, 0, 0, 2]);
        assert_eq!(m.sddmm_mean_latency(), Duration::from_micros(300));
        // shard grain too
        assert_eq!(m.shard_executions(), 0);
        assert_eq!(m.sddmm_shard_executions(), 2);
        assert_eq!(m.sddmm_shard_kernel_counts(), [0, 1, 1, 0]);
        assert_eq!(m.sddmm_shard_mean_latency(), Duration::from_micros(100));
        let s = m.summary();
        assert!(s.contains("sddmm[requests=2"), "{s}");
    }

    #[test]
    fn histograms_are_banked_per_op_grain_and_kernel() {
        let m = Metrics::default();
        m.record(KernelKind::SrRs, Duration::from_micros(100));
        m.record_shard(KernelKind::SrWb, Duration::from_micros(20));
        m.record_sddmm(KernelKind::PrRs, Duration::from_micros(400));
        m.record_sddmm_shard(KernelKind::PrWb, Duration::from_micros(30));
        let cases = [
            (SparseOp::Spmm, Grain::Request, KernelKind::SrRs, 100_000u64),
            (SparseOp::Spmm, Grain::Shard, KernelKind::SrWb, 20_000),
            (SparseOp::Sddmm, Grain::Request, KernelKind::PrRs, 400_000),
            (SparseOp::Sddmm, Grain::Shard, KernelKind::PrWb, 30_000),
        ];
        for (op, grain, kernel, ns) in cases {
            let snap = m.latency_histogram(op, grain, kernel);
            assert_eq!(snap.count, 1, "{op:?}/{grain:?}/{kernel:?}");
            assert_eq!(snap.sum, ns);
            // every other kernel's histogram in the same bank is empty
            for other in KernelKind::ALL {
                if other != kernel {
                    assert!(m.latency_histogram(op, grain, other).is_empty());
                }
            }
            let merged = m.latency_histogram_merged(op, grain);
            assert_eq!(merged.count, 1);
            assert_eq!(merged.max, ns);
        }
    }

    #[test]
    fn cache_and_admission_counters_are_opt_in_sections() {
        let m = Metrics::default();
        let base = m.summary();
        assert!(!base.contains("cache["), "{base}");
        assert!(!base.contains("queue["), "{base}");
        m.record_cache_miss();
        m.record_cache_hit();
        m.record_cache_hit();
        m.record_cache_evictions(0); // no-op
        m.record_cache_evictions(3);
        assert_eq!(m.cache_hits(), 2);
        assert_eq!(m.cache_misses(), 1);
        assert_eq!(m.cache_evictions(), 3);
        m.record_queue_depth(4);
        m.record_queue_depth(9);
        m.record_queue_depth(2);
        m.record_rejection();
        assert_eq!(m.max_queue_depth(), 9);
        assert_eq!(m.rejections(), 1);
        let s = m.summary();
        assert!(s.contains("cache[hits=2 misses=1 evictions=3]"), "{s}");
        assert!(s.contains("queue[max_depth=9 rejected=1]"), "{s}");
    }

    #[test]
    fn cost_ewma_tracks_observations() {
        let m = Metrics::default();
        assert_eq!(m.cost(0, KernelKind::SrRs), None);
        assert_eq!(m.total_cost_observations(), 0);
        m.observe_cost(0, KernelKind::SrRs, 1.0);
        assert_eq!(m.cost(0, KernelKind::SrRs), Some(1.0), "first seeds");
        m.observe_cost(0, KernelKind::SrRs, 2.0);
        let blended = m.cost(0, KernelKind::SrRs).unwrap();
        assert!((blended - (1.0 + COST_EWMA_ALPHA)).abs() < 1e-12, "{blended}");
        assert_eq!(m.cost_observations(0, KernelKind::SrRs), 2);
        // cells are independent
        assert_eq!(m.cost(0, KernelKind::PrWb), None);
        assert_eq!(m.cost(COST_BUCKETS - 1, KernelKind::SrRs), None);
        // garbage observations are dropped
        m.observe_cost(1, KernelKind::PrRs, f64::NAN);
        m.observe_cost(1, KernelKind::PrRs, 0.0);
        m.observe_cost(1, KernelKind::PrRs, -1.0);
        assert_eq!(m.cost(1, KernelKind::PrRs), None);
        assert_eq!(m.total_cost_observations(), 2);
    }

    #[test]
    fn reset_cost_bucket_clears_one_bucket_only() {
        let m = Metrics::default();
        m.observe_cost(2, KernelKind::SrRs, 1.0);
        m.observe_cost(2, KernelKind::PrWb, 3.0);
        m.observe_cost(5, KernelKind::SrRs, 7.0);
        m.reset_cost_bucket(2);
        assert_eq!(m.cost(2, KernelKind::SrRs), None);
        assert_eq!(m.cost(2, KernelKind::PrWb), None);
        assert_eq!(m.cost_observations(2, KernelKind::SrRs), 0);
        // other buckets keep their evidence
        assert_eq!(m.cost(5, KernelKind::SrRs), Some(7.0));
        assert_eq!(m.total_cost_observations(), 1);
        // the cleared cell re-seeds from the next observation
        m.observe_cost(2, KernelKind::SrRs, 4.0);
        assert_eq!(m.cost(2, KernelKind::SrRs), Some(4.0));
    }

    #[test]
    fn cost_ewma_concurrent_observers_converge() {
        let m = std::sync::Arc::new(Metrics::default());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..500 {
                        m.observe_cost(3, KernelKind::SrWb, 2.0);
                    }
                });
            }
        });
        assert_eq!(m.cost_observations(3, KernelKind::SrWb), 2000);
        let c = m.cost(3, KernelKind::SrWb).unwrap();
        assert!((c - 2.0).abs() < 1e-6, "constant stream converges: {c}");
    }

    #[test]
    fn concurrent_recording() {
        let m = std::sync::Arc::new(Metrics::default());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.record(KernelKind::SrWb, Duration::from_nanos(10));
                    }
                });
            }
        });
        assert_eq!(m.requests(), 8000);
        assert_eq!(m.kernel_counts()[1], 8000);
        let snap = m.latency_histogram(SparseOp::Spmm, Grain::Request, KernelKind::SrWb);
        assert_eq!(snap.count, 8000);
        assert_eq!(snap.sum, 80_000);
        assert_eq!(snap.counts.iter().sum::<u64>(), 8000);
    }
}
