//! Dense-width batching: coalesce narrow SpMM requests on the same matrix
//! into one wider artifact invocation.
//!
//! In GNN serving, the dense width N *is* the batch axis (feature columns
//! / embedding width). The artifact library is compiled at fixed widths
//! {1, 4, 32, 128}; a stream of N=1 requests on the same matrix wastes a
//! bucket each, so the batcher packs pending columns side-by-side until a
//! bucket width (or the flush deadline) is reached, runs one SpMM, and
//! splits the result columns back per request.
//!
//! SDDMM requests ([`Batcher::submit_sddmm`]) ride the same outcome
//! plumbing but execute immediately: each carries its own `(U, V)` pair,
//! so there is no width axis to coalesce along. Results are op-tagged
//! via [`BatchedResult::op`].

use super::engine::{MatrixHandle, SpmmEngine};
use crate::kernels::SparseOp;
use crate::obs::trace::{self, Trace, TraceHandle};
use crate::sparse::DenseMatrix;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// One pending request: a dense operand, where to deliver the result,
/// and the serving-layer trace riding the request (if admitted through
/// [`Server::submit`](super::server::Server::submit)).
struct Pending {
    x: DenseMatrix,
    tag: u64,
    trace: Option<Arc<Trace>>,
}

/// Per-request result.
#[derive(Debug)]
pub struct BatchedResult {
    /// The caller's correlation id from the submitted request.
    pub tag: u64,
    /// Which sparse op produced this result.
    pub op: SparseOp,
    /// This request's columns of the batched execution result. For
    /// [`SparseOp::Sddmm`] requests this is the sampled value vector as
    /// an `nnz × 1` column (the pattern lives with the registered
    /// matrix).
    pub y: DenseMatrix,
    /// how many requests shared the executed artifact call
    pub batch_size: usize,
}

/// One failed batch execution: the engine error together with the tags
/// of every request that was in the batch, so a caller can answer each
/// affected requester instead of losing them.
#[derive(Debug)]
pub struct FlushError {
    /// Tags of the requests consumed by the failed batch.
    pub tags: Vec<u64>,
    /// The underlying engine error.
    pub error: anyhow::Error,
}

/// Outcome of a flush: per-request results of the batches that executed,
/// plus a [`FlushError`] per batch that did not. A multi-matrix flush
/// continues past a failing matrix, so one bad batch cannot take down
/// unrelated pending requests.
#[derive(Debug, Default)]
pub struct FlushOutcome {
    /// Results of the successfully executed batches.
    pub results: Vec<BatchedResult>,
    /// One entry per batch whose execution failed.
    pub failures: Vec<FlushError>,
}

/// Width-coalescing batcher. Not thread-safe by itself; the server wraps
/// it in its worker loop.
///
/// Queues are keyed by [`SpmmEngine::batch_key`], not by handle: on a
/// cached engine, distinct handles registered from content-identical
/// matrices share one queue (each queue remembers a representative
/// handle to execute with), so cross-client traffic against the same
/// graph coalesces at the same grain the prepared-matrix cache dedupes
/// at.
pub struct Batcher<'e> {
    engine: &'e SpmmEngine,
    /// max combined width before a forced flush (should equal the widest
    /// artifact bucket)
    pub max_width: usize,
    queues: HashMap<u64, (MatrixHandle, Vec<Pending>)>,
}

impl<'e> Batcher<'e> {
    /// New batcher over an engine.
    pub fn new(engine: &'e SpmmEngine, max_width: usize) -> Self {
        Self {
            engine,
            max_width,
            queues: HashMap::new(),
        }
    }

    /// Enqueue a request; flushes automatically when the queue reaches the
    /// bucket width, returning any outcome that flush produced.
    ///
    /// The request is validated **before** it is queued: an `Err` here
    /// means this request alone was rejected (unknown handle, inner
    /// dimension mismatch) and no pending request was touched — a bad
    /// operand must not poison the batch it would have been packed into.
    pub fn submit(&mut self, h: MatrixHandle, x: DenseMatrix, tag: u64) -> Result<FlushOutcome> {
        self.submit_traced(h, x, tag, None)
    }

    /// [`Batcher::submit`] with a serving-layer trace riding the request.
    /// The trace follows the request through the queue: the batch it
    /// flushes in executes under the first traced member's context (so
    /// the engine's dispatch/kernel spans land there), every other traced
    /// member records the shared execution as a raw `batch_join`
    /// interval, and each member's trace is committed to the engine's
    /// flight recorder when its batch settles — on success, batch
    /// failure, or pre-queue rejection alike.
    pub fn submit_traced(
        &mut self,
        h: MatrixHandle,
        x: DenseMatrix,
        tag: u64,
        trace: Option<Arc<Trace>>,
    ) -> Result<FlushOutcome> {
        let expected = match self.engine.features(h) {
            Ok(f) => f.cols,
            Err(e) => {
                self.commit_trace(&trace);
                return Err(e);
            }
        };
        if x.rows != expected {
            self.engine.metrics.record_error();
            self.commit_trace(&trace);
            return Err(anyhow!(
                "inner dimension mismatch: matrix has {expected} cols, X has {} rows",
                x.rows
            ));
        }
        let key = match self.engine.batch_key(h) {
            Ok(key) => key,
            Err(e) => {
                self.commit_trace(&trace);
                return Err(e);
            }
        };
        let entry = self.queues.entry(key).or_insert_with(|| (h, Vec::new()));
        entry.1.push(Pending { x, tag, trace });
        let width: usize = entry.1.iter().map(|p| p.x.cols).sum();
        if width >= self.max_width {
            Ok(self.flush(key))
        } else {
            Ok(FlushOutcome::default())
        }
    }

    fn commit_trace(&self, trace: &Option<Arc<Trace>>) {
        if let Some(t) = trace {
            self.engine.metrics.recorder().commit(t);
        }
    }

    /// Submit an SDDMM request; executes immediately and returns its
    /// outcome. SDDMM has no width-coalescing axis — each request carries
    /// its own `(U, V)` pair, and concatenating dot products along `d`
    /// would change every result — so there is no queue to protect with a
    /// pre-check: operand validation is the engine's
    /// (`PreparedOperand::check_sddmm_operands`, one validation site),
    /// and any failure — unknown handle, shape mismatch, execution
    /// error — is reported as an op-tagged [`FlushError`] carrying this
    /// request's tag, so no replier leaks. The `Result` wrapper mirrors
    /// [`Batcher::submit`]'s signature; this path itself never errors.
    pub fn submit_sddmm(
        &mut self,
        h: MatrixHandle,
        u: DenseMatrix,
        v: DenseMatrix,
        tag: u64,
    ) -> Result<FlushOutcome> {
        self.submit_sddmm_traced(h, u, v, tag, None)
    }

    /// [`Batcher::submit_sddmm`] with a serving-layer trace riding the
    /// request: the engine's dispatch/kernel spans for the (unbatched)
    /// execution land in it, and it is committed to the engine's flight
    /// recorder before this returns.
    pub fn submit_sddmm_traced(
        &mut self,
        h: MatrixHandle,
        u: DenseMatrix,
        v: DenseMatrix,
        tag: u64,
        trace: Option<Arc<Trace>>,
    ) -> Result<FlushOutcome> {
        let mut outcome = FlushOutcome::default();
        let scope = trace.as_ref().map(|t| trace::attach(&TraceHandle::of(t)));
        let result = self.engine.sddmm(h, &u, &v);
        drop(scope);
        self.commit_trace(&trace);
        match result {
            Ok(resp) => {
                let nnz = resp.values.len();
                outcome.results.push(BatchedResult {
                    tag,
                    op: SparseOp::Sddmm,
                    y: DenseMatrix::from_vec(nnz, 1, resp.values),
                    batch_size: 1,
                });
            }
            Err(error) => outcome.failures.push(FlushError {
                tags: vec![tag],
                error,
            }),
        }
        Ok(outcome)
    }

    /// Pending request count across all queues.
    pub fn pending(&self) -> usize {
        self.queues.values().map(|(_, q)| q.len()).sum()
    }

    /// Flush one coalescing queue. A failed execution is reported as a
    /// [`FlushError`] carrying every consumed tag — never silently
    /// dropped.
    fn flush(&mut self, key: u64) -> FlushOutcome {
        let mut outcome = FlushOutcome::default();
        let (h, q) = match self.queues.remove(&key) {
            Some((h, q)) if !q.is_empty() => (h, q),
            _ => return outcome,
        };
        // all operands share x.rows: submit validated each against the
        // registered matrix's inner dimension
        let k = q[0].x.rows;
        let total: usize = q.iter().map(|p| p.x.cols).sum();
        // pack columns side by side
        let mut combined = DenseMatrix::zeros(k, total);
        let mut off = 0;
        for p in &q {
            for r in 0..k {
                combined.data[r * total + off..r * total + off + p.x.cols]
                    .copy_from_slice(p.x.row(r));
            }
            off += p.x.cols;
        }
        // Execute under the first traced member's context, so the
        // engine's dispatch/kernel spans for the shared execution land
        // in exactly one trace; every other traced member records the
        // same interval as a raw `batch_join` span. All member traces
        // are committed here — the batch settles them, pass or fail.
        let primary = q.iter().position(|p| p.trace.is_some());
        let starts: Vec<u64> = q
            .iter()
            .map(|p| p.trace.as_ref().map_or(0, |t| t.elapsed_ns()))
            .collect();
        let scope = primary.map(|i| {
            trace::attach(&TraceHandle::of(
                q[i].trace.as_ref().expect("primary has a trace"),
            ))
        });
        let mut batch_span = trace::span("batch");
        batch_span.set_attr("batch_size", q.len());
        batch_span.set_attr("width", total);
        let executed = self.engine.spmm(h, &combined);
        batch_span.end();
        drop(scope);
        for (i, p) in q.iter().enumerate() {
            let Some(t) = &p.trace else { continue };
            if primary != Some(i) {
                t.record_raw(
                    "batch_join",
                    starts[i],
                    t.elapsed_ns(),
                    vec![
                        ("batch_size", q.len().to_string()),
                        ("width", total.to_string()),
                    ],
                );
            }
            self.engine.metrics.recorder().commit(t);
        }
        let resp = match executed {
            Ok(resp) => resp,
            Err(error) => {
                outcome.failures.push(FlushError {
                    tags: q.iter().map(|p| p.tag).collect(),
                    error,
                });
                return outcome;
            }
        };
        // split result columns back out
        let rows = resp.y.rows;
        let mut off = 0;
        for p in &q {
            let mut y = DenseMatrix::zeros(rows, p.x.cols);
            for r in 0..rows {
                y.data[r * p.x.cols..(r + 1) * p.x.cols]
                    .copy_from_slice(&resp.y.data[r * total + off..r * total + off + p.x.cols]);
            }
            off += p.x.cols;
            outcome.results.push(BatchedResult {
                tag: p.tag,
                op: SparseOp::Spmm,
                y,
                batch_size: q.len(),
            });
        }
        outcome
    }

    /// Flush everything (deadline path), continuing past failing batches
    /// so one matrix's error cannot starve the others.
    pub fn flush_all(&mut self) -> FlushOutcome {
        let keys: Vec<u64> = self.queues.keys().copied().collect();
        let mut outcome = FlushOutcome::default();
        for key in keys {
            let one = self.flush(key);
            outcome.results.extend(one.results);
            outcome.failures.extend(one.failures);
        }
        outcome
    }
}

#[cfg(test)]
mod tests {
    // Invariant tests that don't need artifacts: column packing/splitting
    // round-trips. Full batcher tests (through PJRT) are in rust/tests/.
    use crate::sparse::DenseMatrix;

    /// The packing scheme used by the batcher, extracted for direct
    /// property testing.
    fn pack_cols(parts: &[DenseMatrix]) -> DenseMatrix {
        let k = parts[0].rows;
        let total: usize = parts.iter().map(|p| p.cols).sum();
        let mut combined = DenseMatrix::zeros(k, total);
        let mut off = 0;
        for p in parts {
            for r in 0..k {
                combined.data[r * total + off..r * total + off + p.cols]
                    .copy_from_slice(p.row(r));
            }
            off += p.cols;
        }
        combined
    }

    #[test]
    fn column_packing_roundtrip() {
        use crate::util::proptest::run_prop;
        run_prop("batcher column packing", 40, |g| {
            let k = g.dim().max(2);
            let nparts = g.usize_in(1, 5);
            let parts: Vec<DenseMatrix> = (0..nparts)
                .map(|_| {
                    let c = g.usize_in(1, 5);
                    DenseMatrix::from_vec(k, c, g.vec_f32(k * c))
                })
                .collect();
            let combined = pack_cols(&parts);
            // unpack and compare
            let total = combined.cols;
            let mut off = 0;
            for p in &parts {
                for r in 0..k {
                    let got = &combined.data[r * total + off..r * total + off + p.cols];
                    if got != p.row(r) {
                        return Err(format!("row {r} mismatch at offset {off}"));
                    }
                }
                off += p.cols;
            }
            Ok(())
        });
    }
}
