//! Dense-width batching: coalesce narrow SpMM requests on the same matrix
//! into one wider artifact invocation.
//!
//! In GNN serving, the dense width N *is* the batch axis (feature columns
//! / embedding width). The artifact library is compiled at fixed widths
//! {1, 4, 32, 128}; a stream of N=1 requests on the same matrix wastes a
//! bucket each, so the batcher packs pending columns side-by-side until a
//! bucket width (or the flush deadline) is reached, runs one SpMM, and
//! splits the result columns back per request.

use super::engine::{MatrixHandle, SpmmEngine};
use crate::sparse::DenseMatrix;
use anyhow::Result;
use std::collections::HashMap;

/// One pending request: a dense operand and where to deliver the result.
struct Pending {
    x: DenseMatrix,
    tag: u64,
}

/// Per-request result.
#[derive(Debug)]
pub struct BatchedResult {
    pub tag: u64,
    pub y: DenseMatrix,
    /// how many requests shared the executed artifact call
    pub batch_size: usize,
}

/// Width-coalescing batcher. Not thread-safe by itself; the server wraps
/// it in its worker loop.
pub struct Batcher<'e> {
    engine: &'e SpmmEngine,
    /// max combined width before a forced flush (should equal the widest
    /// artifact bucket)
    pub max_width: usize,
    queues: HashMap<MatrixHandle, Vec<Pending>>,
}

impl<'e> Batcher<'e> {
    /// New batcher over an engine.
    pub fn new(engine: &'e SpmmEngine, max_width: usize) -> Self {
        Self {
            engine,
            max_width,
            queues: HashMap::new(),
        }
    }

    /// Enqueue a request; flushes automatically when the queue reaches the
    /// bucket width. Returns any results produced by an automatic flush.
    pub fn submit(
        &mut self,
        h: MatrixHandle,
        x: DenseMatrix,
        tag: u64,
    ) -> Result<Vec<BatchedResult>> {
        let q = self.queues.entry(h).or_default();
        q.push(Pending { x, tag });
        let width: usize = q.iter().map(|p| p.x.cols).sum();
        if width >= self.max_width {
            self.flush_one(h)
        } else {
            Ok(Vec::new())
        }
    }

    /// Pending request count across all matrices.
    pub fn pending(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    /// Flush one matrix's queue.
    pub fn flush_one(&mut self, h: MatrixHandle) -> Result<Vec<BatchedResult>> {
        let q = match self.queues.remove(&h) {
            Some(q) if !q.is_empty() => q,
            _ => return Ok(Vec::new()),
        };
        let k = q[0].x.rows;
        let total: usize = q.iter().map(|p| p.x.cols).sum();
        // pack columns side by side
        let mut combined = DenseMatrix::zeros(k, total);
        let mut off = 0;
        for p in &q {
            for r in 0..k {
                combined.data[r * total + off..r * total + off + p.x.cols]
                    .copy_from_slice(p.x.row(r));
            }
            off += p.x.cols;
        }
        let resp = self.engine.spmm(h, &combined)?;
        // split result columns back out
        let mut out = Vec::with_capacity(q.len());
        let rows = resp.y.rows;
        let mut off = 0;
        for p in &q {
            let mut y = DenseMatrix::zeros(rows, p.x.cols);
            for r in 0..rows {
                y.data[r * p.x.cols..(r + 1) * p.x.cols]
                    .copy_from_slice(&resp.y.data[r * total + off..r * total + off + p.x.cols]);
            }
            off += p.x.cols;
            out.push(BatchedResult {
                tag: p.tag,
                y,
                batch_size: q.len(),
            });
        }
        Ok(out)
    }

    /// Flush everything (deadline path).
    pub fn flush_all(&mut self) -> Result<Vec<BatchedResult>> {
        let handles: Vec<MatrixHandle> = self.queues.keys().copied().collect();
        let mut out = Vec::new();
        for h in handles {
            out.extend(self.flush_one(h)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    // Invariant tests that don't need artifacts: column packing/splitting
    // round-trips. Full batcher tests (through PJRT) are in rust/tests/.
    use crate::sparse::DenseMatrix;

    /// The packing scheme used by the batcher, extracted for direct
    /// property testing.
    fn pack_cols(parts: &[DenseMatrix]) -> DenseMatrix {
        let k = parts[0].rows;
        let total: usize = parts.iter().map(|p| p.cols).sum();
        let mut combined = DenseMatrix::zeros(k, total);
        let mut off = 0;
        for p in parts {
            for r in 0..k {
                combined.data[r * total + off..r * total + off + p.cols]
                    .copy_from_slice(p.row(r));
            }
            off += p.cols;
        }
        combined
    }

    #[test]
    fn column_packing_roundtrip() {
        use crate::util::proptest::run_prop;
        run_prop("batcher column packing", 40, |g| {
            let k = g.dim().max(2);
            let nparts = g.usize_in(1, 5);
            let parts: Vec<DenseMatrix> = (0..nparts)
                .map(|_| {
                    let c = g.usize_in(1, 5);
                    DenseMatrix::from_vec(k, c, g.vec_f32(k * c))
                })
                .collect();
            let combined = pack_cols(&parts);
            // unpack and compare
            let total = combined.cols;
            let mut off = 0;
            for p in &parts {
                for r in 0..k {
                    let got = &combined.data[r * total + off..r * total + off + p.cols];
                    if got != p.row(r) {
                        return Err(format!("row {r} mismatch at offset {off}"));
                    }
                }
                off += p.cols;
            }
            Ok(())
        });
    }
}
