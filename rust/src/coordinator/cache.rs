//! Prepared-matrix cache: a content-fingerprinted, byte-budgeted LRU
//! registry of backend-prepared state.
//!
//! The paper's adaptive selection pays off in the prepare-once /
//! execute-many regime; serving traffic only reaches that regime if
//! *preparation itself* is deduplicated across clients. Every client that
//! registers a graph pays `SpmmBackend::prepare` — O(nnz) format
//! conversion — unless someone already prepared the same content. This
//! cache keys prepared state by [`crate::sparse::CsrMatrix::fingerprint`]
//! (a 64-bit content hash), so repeated traffic against the same graph
//! skips preparation entirely, across handles, threads and clients. The
//! fingerprint is trusted without a full content comparison — a 64-bit
//! collision would silently alias two matrices; that risk is vanishing
//! for organic traffic but the hash is not adversarially collision
//! resistant, so don't expose a cached engine to hostile matrix content.
//!
//! Eviction is least-recently-used under a byte budget. Costs are
//! supplied by the caller (the engine passes
//! [`crate::sparse::CsrMatrix::heap_bytes`], a backend-independent proxy
//! for prepared-state size). An entry larger than the whole budget is
//! not cached at all — it would immediately evict everything else for a
//! reuse that cannot happen under that budget anyway.
//!
//! The cache is value-generic: [`crate::coordinator::SpmmEngine`]
//! instantiates it with its private registration record, and the tests
//! here exercise the policy with plain integers. See `DESIGN.md`
//! §Serving layer.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One resident entry: the shared value, its billed size, and the
/// logical timestamp of its last touch.
struct Entry<T> {
    value: Arc<T>,
    bytes: usize,
    last_used: u64,
}

/// Mutex-guarded cache state: the entries, their total billed bytes, and
/// a monotonic tick that orders touches for LRU eviction.
struct Inner<T> {
    entries: HashMap<u64, Entry<T>>,
    bytes: usize,
    tick: u64,
}

/// Byte-budgeted LRU cache from content fingerprints to shared values.
///
/// All operations take one short mutex; values are handed out as
/// [`Arc`] clones so hits never copy the prepared state.
pub struct PreparedCache<T> {
    budget: usize,
    inner: Mutex<Inner<T>>,
}

impl<T> PreparedCache<T> {
    /// Empty cache that will evict to stay within `budget_bytes`.
    pub fn new(budget_bytes: usize) -> Self {
        Self {
            budget: budget_bytes,
            inner: Mutex::new(Inner {
                entries: HashMap::new(),
                bytes: 0,
                tick: 0,
            }),
        }
    }

    /// The configured byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    /// Number of resident entries.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total billed bytes of the resident entries.
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Look up a fingerprint; a hit refreshes the entry's LRU position.
    pub fn get(&self, fingerprint: u64) -> Option<Arc<T>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let entry = inner.entries.get_mut(&fingerprint)?;
        entry.last_used = tick;
        Some(entry.value.clone())
    }

    /// Insert a value billed at `bytes`, evicting least-recently-used
    /// entries (never the one just inserted) until the budget holds
    /// again. Returns how many entries were evicted. Re-inserting a
    /// resident fingerprint replaces it without double-billing; a value
    /// larger than the whole budget is not cached (returns 0).
    pub fn insert(&self, fingerprint: u64, value: Arc<T>, bytes: usize) -> u64 {
        if bytes > self.budget {
            return 0;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let entry = Entry {
            value,
            bytes,
            last_used: tick,
        };
        if let Some(old) = inner.entries.insert(fingerprint, entry) {
            inner.bytes -= old.bytes;
        }
        inner.bytes += bytes;
        let mut evicted = 0;
        while inner.bytes > self.budget {
            let victim = inner
                .entries
                .iter()
                .filter(|&(&fp, _)| fp != fingerprint)
                .min_by_key(|&(_, e)| e.last_used)
                .map(|(&fp, _)| fp);
            match victim {
                Some(fp) => {
                    let old = inner.entries.remove(&fp).expect("victim is resident");
                    inner.bytes -= old.bytes;
                    evicted += 1;
                }
                None => break,
            }
        }
        evicted
    }

    /// Drop one fingerprint's entry, returning whether it was resident.
    /// The engine calls this on `unregister` (dead prepared state must
    /// not sit on the byte budget until LRU pressure) and on delta
    /// application (the pre-mutation fingerprint can never be requested
    /// again — registration re-fingerprints, and the epoch moved).
    pub fn remove(&self, fingerprint: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        match inner.entries.remove(&fingerprint) {
            Some(old) => {
                inner.bytes -= old.bytes;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(v: usize) -> Arc<usize> {
        Arc::new(v)
    }

    #[test]
    fn hit_returns_shared_value_and_miss_returns_none() {
        let cache: PreparedCache<usize> = PreparedCache::new(1000);
        assert!(cache.is_empty());
        assert_eq!(cache.get(7), None);
        assert_eq!(cache.insert(7, entry(70), 100), 0);
        assert_eq!(*cache.get(7).unwrap(), 70);
        assert_eq!((cache.len(), cache.bytes()), (1, 100));
        assert_eq!(cache.budget_bytes(), 1000);
    }

    #[test]
    fn evicts_least_recently_used_under_byte_budget() {
        let cache: PreparedCache<usize> = PreparedCache::new(100);
        assert_eq!(cache.insert(1, entry(1), 40), 0);
        assert_eq!(cache.insert(2, entry(2), 40), 0);
        // touch 1 so 2 is now the LRU entry
        assert!(cache.get(1).is_some());
        // 40 + 40 + 40 > 100 → evict exactly one entry: 2
        assert_eq!(cache.insert(3, entry(3), 40), 1);
        assert!(cache.get(2).is_none());
        assert!(cache.get(1).is_some());
        assert!(cache.get(3).is_some());
        assert_eq!((cache.len(), cache.bytes()), (2, 80));
    }

    #[test]
    fn one_large_insert_can_evict_many() {
        let cache: PreparedCache<usize> = PreparedCache::new(100);
        for fp in 0..4u64 {
            cache.insert(fp, entry(fp as usize), 25);
        }
        assert_eq!(cache.len(), 4);
        // 100 + 50 > 100 → evict fingerprints 0 and 1 (oldest first)
        assert_eq!(cache.insert(9, entry(9), 50), 2);
        assert!(cache.get(0).is_none());
        assert!(cache.get(1).is_none());
        assert!(cache.get(9).is_some());
        assert_eq!((cache.len(), cache.bytes()), (3, 100));
    }

    #[test]
    fn oversized_values_are_not_cached() {
        let cache: PreparedCache<usize> = PreparedCache::new(100);
        cache.insert(1, entry(1), 60);
        assert_eq!(cache.insert(2, entry(2), 101), 0);
        assert!(cache.get(2).is_none());
        // the resident entry was not disturbed
        assert!(cache.get(1).is_some());
        assert_eq!((cache.len(), cache.bytes()), (1, 60));
    }

    #[test]
    fn remove_releases_bytes_and_reports_residency() {
        let cache: PreparedCache<usize> = PreparedCache::new(100);
        cache.insert(1, entry(1), 40);
        cache.insert(2, entry(2), 30);
        assert!(cache.remove(1));
        assert_eq!((cache.len(), cache.bytes()), (1, 30));
        assert!(cache.get(1).is_none());
        assert!(!cache.remove(1), "second remove is a no-op");
        assert!(!cache.remove(99), "absent fingerprint is a no-op");
        // the freed budget is usable again without eviction
        assert_eq!(cache.insert(3, entry(3), 70), 0);
        assert_eq!((cache.len(), cache.bytes()), (2, 100));
    }

    #[test]
    fn reinsert_replaces_without_double_billing() {
        let cache: PreparedCache<usize> = PreparedCache::new(100);
        cache.insert(5, entry(50), 60);
        assert_eq!(cache.insert(5, entry(51), 80), 0);
        assert_eq!(*cache.get(5).unwrap(), 51);
        assert_eq!((cache.len(), cache.bytes()), (1, 80));
    }
}
