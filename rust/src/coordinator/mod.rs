//! The coordinator — Layer 3's service surface.
//!
//! Productizes the paper's adaptive-kernel contribution: a caller
//! registers sparse matrices once ([`engine::SpmmEngine`]), then submits
//! SpMM requests; the engine extracts features, picks a kernel via the
//! Fig.-4 rules, routes to the right AOT artifact bucket, packs operands,
//! and executes on the PJRT runtime. [`batcher`] coalesces narrow
//! requests along the dense-width axis (the paper's own batching axis: N
//! *is* the batch dimension in GNN workloads); [`metrics`] tracks
//! per-kernel counts and latency; [`server`] runs the request loop.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod pack;
pub mod server;

pub use engine::{MatrixHandle, SpmmEngine};
