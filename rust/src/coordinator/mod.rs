//! The coordinator — Layer 3's service surface.
//!
//! Productizes the paper's adaptive-kernel contribution as a serving
//! stack: a caller registers sparse matrices once
//! ([`engine::SpmmEngine`]), then submits SpMM requests; the engine
//! extracts features, picks a kernel via the Fig.-4 rules, and executes
//! through its [`crate::backend::SpmmBackend`] — the native CPU kernels
//! by default, the size-routed sharded composition under
//! [`SpmmEngine::serving`], or the AOT artifact path on the PJRT runtime
//! with the `pjrt` feature.
//!
//! - [`cache`] — the prepared-matrix registry: content-fingerprinted,
//!   byte-budgeted LRU reuse of backend-prepared state, so repeated
//!   traffic against the same graph skips preparation entirely;
//! - [`batcher`] — coalesces narrow requests along the dense-width axis
//!   (the paper's own batching axis: N *is* the batch dimension in GNN
//!   workloads);
//! - [`server`] — the concurrent request path: N workers over one shared
//!   engine, per-matrix routing, an admission bound, graceful shutdown;
//! - [`metrics`] — per-kernel counts, latency, shard/cache/admission
//!   telemetry.
//!
//! All of them are backend-agnostic. `pack` (bucket-shaped operand
//! packing for fixed-shape artifacts) is only meaningful for the PJRT
//! backend and is gated with it. See `DESIGN.md` §Serving layer for the
//! deployment shape this module implements.
#![warn(missing_docs)]

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod pack;
pub mod server;

pub use cache::PreparedCache;
pub use engine::{MatrixHandle, SpmmEngine};
pub use server::{Server, ServerConfig};
