//! The coordinator — Layer 3's service surface.
//!
//! Productizes the paper's adaptive-kernel contribution: a caller
//! registers sparse matrices once ([`engine::SpmmEngine`]), then submits
//! SpMM requests; the engine extracts features, picks a kernel via the
//! Fig.-4 rules, and executes through its [`crate::backend::SpmmBackend`]
//! — the native CPU kernels by default, or the AOT artifact path on the
//! PJRT runtime with the `pjrt` feature. [`batcher`] coalesces narrow
//! requests along the dense-width axis (the paper's own batching axis: N
//! *is* the batch dimension in GNN workloads); [`metrics`] tracks
//! per-kernel counts and latency; [`server`] runs the request loop. All
//! of them are backend-agnostic.
//!
//! `pack` (bucket-shaped operand packing for fixed-shape artifacts) is
//! only meaningful for the PJRT backend and is gated with it.

pub mod batcher;
pub mod engine;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod pack;
pub mod server;

pub use engine::{MatrixHandle, SpmmEngine};
