//! Coordinator integration on the native backend — the default-feature
//! end-to-end test of the full serving stack: register → select → batch →
//! serve → metrics, with zero artifacts and zero libxla.
//!
//! Mirrors `integration_coordinator.rs` (which drives the same stack
//! through PJRT artifacts and is gated behind the `pjrt` feature).

use ge_spmm::coordinator::batcher::Batcher;
use ge_spmm::coordinator::server::{serve, Request, ServerConfig, ServerReply};
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::kernels::dense::spmm_reference;
use ge_spmm::kernels::KernelKind;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;
use ge_spmm::util::proptest::assert_close;
use std::sync::mpsc;
use std::time::Duration;

fn matrix(seed: u64) -> CsrMatrix {
    let mut rng = Xoshiro256::seeded(seed);
    CsrMatrix::from_coo(&CooMatrix::random_uniform(120, 120, 0.05, &mut rng))
}

#[test]
fn every_kernel_reachable_through_engine_matches_reference() {
    let engine = SpmmEngine::native();
    let a = matrix(4001);
    let h = engine.register(a.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(4002);
    for n in [1usize, 4, 32, 128] {
        let x = DenseMatrix::random(120, n, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(120, n);
        spmm_reference(&a, &x, &mut want);
        for kind in KernelKind::ALL {
            let resp = engine.spmm_with(h, &x, kind).unwrap();
            assert_eq!(resp.artifact, format!("native/{}", kind.label()));
            assert_close(&resp.y.data, &want.data, 1e-4, 1e-4)
                .unwrap_or_else(|m| panic!("{} n={n}: {m}", kind.label()));
        }
    }
    // every request accounted for, exactly once, under some kernel
    assert_eq!(engine.metrics.requests(), 16);
    assert_eq!(engine.metrics.kernel_counts(), [4, 4, 4, 4]);
    assert_eq!(engine.metrics.errors(), 0);
}

#[test]
fn batcher_coalesces_and_results_match_unbatched() {
    let engine = SpmmEngine::native();
    let a = matrix(4003);
    let h = engine.register(a.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(4004);

    let xs: Vec<DenseMatrix> = (0..4)
        .map(|_| DenseMatrix::random(120, 1, 1.0, &mut rng))
        .collect();

    let mut batcher = Batcher::new(&engine, 4);
    let mut results = Vec::new();
    for (i, x) in xs.iter().enumerate() {
        let out = batcher.submit(h, x.clone(), i as u64).unwrap();
        assert!(out.failures.is_empty());
        results.extend(out.results);
    }
    // 4 columns = max_width → auto-flush happened
    assert_eq!(results.len(), 4);
    assert_eq!(batcher.pending(), 0);
    // exactly one backend execution served all four requests
    assert_eq!(engine.metrics.requests(), 1);
    for r in &results {
        assert_eq!(r.batch_size, 4);
        let x = &xs[r.tag as usize];
        let mut want = DenseMatrix::zeros(120, 1);
        spmm_reference(&a, x, &mut want);
        assert_close(&r.y.data, &want.data, 1e-4, 1e-4)
            .unwrap_or_else(|m| panic!("tag {}: {m}", r.tag));
    }
}

#[test]
fn server_loop_with_concurrent_producers_matches_unbatched() {
    let engine = SpmmEngine::native();
    let a = matrix(4005);
    let b = matrix(4006);
    let ha = engine.register(a.clone()).unwrap();
    let hb = engine.register(b.clone()).unwrap();

    let (tx, rx) = mpsc::channel::<Request>();
    let config = ServerConfig {
        max_width: 4,
        max_delay: Duration::from_millis(5),
        ..ServerConfig::default()
    };

    const PRODUCERS: u64 = 3;
    const PER_PRODUCER: u64 = 6;

    let mut producers = Vec::new();
    for p in 0..PRODUCERS {
        let tx = tx.clone();
        let a = a.clone();
        let b = b.clone();
        producers.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256::seeded(5000 + p);
            let mut pending = Vec::new();
            for i in 0..PER_PRODUCER {
                let tag = p * PER_PRODUCER + i; // globally unique
                let (use_b, n) = ((i % 2) == 1, if i % 3 == 0 { 2 } else { 1 });
                let (h, m) = if use_b { (hb, &b) } else { (ha, &a) };
                let x = DenseMatrix::random(120, n, 1.0, &mut rng);
                let mut want = DenseMatrix::zeros(120, n);
                spmm_reference(m, &x, &mut want);
                let (rtx, rrx) = mpsc::channel();
                tx.send(Request::spmm(h, x, tag, rtx)).unwrap();
                pending.push((tag, want, rrx));
            }
            drop(tx);
            for (tag, want, rrx) in pending {
                match rrx.recv_timeout(Duration::from_secs(60)).unwrap() {
                    ServerReply::Ok(r) => {
                        assert_eq!(r.tag, tag);
                        assert!(r.batch_size >= 1);
                        assert_close(&r.y.data, &want.data, 1e-4, 1e-4)
                            .unwrap_or_else(|m| panic!("tag {tag}: {m}"));
                    }
                    ServerReply::Err(e) => panic!("request {tag} failed: {e}"),
                }
            }
        }));
    }
    drop(tx); // close the channel once all producers finish

    serve(&engine, rx, config);
    for p in producers {
        p.join().unwrap();
    }

    // Metrics add up: every backend execution is counted under exactly one
    // kernel, no errors, and batching can only merge — never drop or
    // duplicate — requests.
    let total = PRODUCERS * PER_PRODUCER;
    let requests = engine.metrics.requests();
    assert!((1..=total).contains(&requests), "requests {requests}");
    assert_eq!(engine.metrics.kernel_counts().iter().sum::<u64>(), requests);
    assert_eq!(engine.metrics.errors(), 0);
    assert!(engine.metrics.mean_latency() > Duration::ZERO);
}

#[test]
fn server_reports_errors_and_metrics_count_them() {
    let engine = SpmmEngine::native();
    let h = engine.register(matrix(4007)).unwrap();

    let (tx, rx) = mpsc::channel::<Request>();
    let (rtx, rrx) = mpsc::channel();
    // wrong inner dimension (119 rows, should be 120) at full batch
    // width so the flush — and the failure — happens immediately
    tx.send(Request::spmm(h, DenseMatrix::zeros(119, 4), 9, rtx))
        .unwrap();
    drop(tx);

    serve(
        &engine,
        rx,
        ServerConfig {
            max_width: 4,
            max_delay: Duration::from_millis(2),
            ..ServerConfig::default()
        },
    );
    match rrx.recv_timeout(Duration::from_secs(10)).unwrap() {
        ServerReply::Err(e) => assert!(e.contains("dimension"), "unexpected error: {e}"),
        ServerReply::Ok(_) => panic!("dimension mismatch must not succeed"),
    }
    assert_eq!(engine.metrics.errors(), 1);
    assert_eq!(engine.metrics.requests(), 0);
}
