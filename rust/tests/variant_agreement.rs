//! Generated-variant agreement (ISSUE 9): the variant registry replaces
//! the closed four-kernel enum, so every entry it generates — tiled row
//! traversals, merge-path spans, alternate segment lengths — must compute
//! the same answer as the dense references. CI runs this binary with and
//! without `--features simd`; the invariants below hold in both
//! configurations because kernels and references share one canonical dot
//! order per configuration.
//!
//! - Every SpMM variant agrees with `spmm_reference` within float
//!   tolerance on arbitrary data, across all four generator families.
//! - The serial-reduction (SR) row-traversal variants are **bit-for-bit**
//!   equal to the reference on arbitrary floats under a serial pool:
//!   tiling the elementwise `j` loop and re-chunking rows reassociate
//!   nothing.
//! - On integer-valued operands every partial sum is exactly
//!   representable, so **all** variants — including the reassociating
//!   workload-balanced and parallel-reduction families at every segment
//!   length — must be bit-for-bit equal under parallel pools.
//! - Every SDDMM variant is **bit-for-bit** equal to `sddmm_reference`
//!   in every configuration (one canonical dot per configuration).
//! - Misusing an entry (wrong op, mismatched segment layout) errors
//!   instead of panicking or silently computing garbage.

use std::collections::HashMap;

use ge_spmm::gen::banded::banded;
use ge_spmm::gen::powerlaw::PowerLawConfig;
use ge_spmm::gen::rmat::RmatConfig;
use ge_spmm::kernels::dense::{sddmm_reference, spmm_reference};
use ge_spmm::kernels::{registry, KernelKind, SparseOp};
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix, SegmentedMatrix};
use ge_spmm::util::proptest::{assert_close, run_prop, Gen};
use ge_spmm::util::threadpool::ThreadPool;

mod common;
use common::int_dense;

/// One matrix from each generator family the selector is tested over:
/// uniform, power-law (heavy tail), banded, R-MAT.
fn gen_matrix(g: &mut Gen) -> CsrMatrix {
    let family = *g.choose(&[0usize, 1, 2, 3]);
    let coo = match family {
        0 => {
            let rows = g.dim() * 2 + 1;
            let cols = g.dim() * 2 + 1;
            let density = g.f64_in(0.02, 0.3);
            CooMatrix::random_uniform(rows, cols, density, g.rng())
        }
        1 => {
            let rows = g.dim() * 4 + 8;
            PowerLawConfig {
                rows,
                cols: rows,
                alpha: 1.7,
                min_row: 1,
                max_row: (rows / 2).max(2),
            }
            .generate(g.rng())
        }
        2 => {
            let n = g.dim() * 2 + 2;
            banded(n, &[-3, -1, 0, 1, 5], g.rng())
        }
        _ => RmatConfig::new(5, 4.0).generate(g.rng()),
    };
    CsrMatrix::from_coo(&coo)
}

/// One segmented layout per distinct segment length in the registry —
/// variants that share a length share the layout, exactly like the
/// backends do.
fn layouts(a: &CsrMatrix) -> HashMap<usize, SegmentedMatrix> {
    let mut lens: Vec<usize> = registry()
        .entries()
        .iter()
        .map(|e| e.variant.seg_len)
        .collect();
    lens.sort_unstable();
    lens.dedup();
    lens.into_iter()
        .map(|l| (l, SegmentedMatrix::from_csr(a, l)))
        .collect()
}

/// Assert bit-for-bit equality with a labelled first-divergence message.
fn assert_bits(actual: &[f32], expect: &[f32], what: &str) -> Result<(), String> {
    if actual.len() != expect.len() {
        return Err(format!("{what}: length {} vs {}", actual.len(), expect.len()));
    }
    for (i, (a, e)) in actual.iter().zip(expect).enumerate() {
        if a.to_bits() != e.to_bits() {
            return Err(format!("{what}: first divergence at {i}: {a:e} vs {e:e}"));
        }
    }
    Ok(())
}

#[test]
fn registry_spans_both_ops_and_all_families() {
    let reg = registry();
    assert!(
        reg.len() >= 12,
        "variant space collapsed: {} entries (want >= 12)",
        reg.len()
    );
    for op in [SparseOp::Spmm, SparseOp::Sddmm] {
        for family in KernelKind::ALL {
            let variants = reg.family_variants(op, family);
            assert!(
                !variants.is_empty(),
                "no generated variants for {}/{}",
                op.label(),
                family.label()
            );
            // the canonical point is always present and listed first
            assert_eq!(variants[0].label, family.label());
        }
    }
}

#[test]
fn every_spmm_variant_agrees_with_the_reference_across_generators() {
    run_prop("variants: spmm vs reference", 32, |g| {
        let a = gen_matrix(g);
        let segs = layouts(&a);
        let n = *g.choose(&[1usize, 4, 8, 32, 33]);
        let x = DenseMatrix::from_vec(a.cols, n, g.vec_f32(a.cols * n));
        let mut want = DenseMatrix::zeros(a.rows, n);
        spmm_reference(&a, &x, &mut want);
        let pool = ThreadPool::new(*g.choose(&[1usize, 2, 4]));
        for e in registry().op_variants(SparseOp::Spmm) {
            let mut y = DenseMatrix::zeros(a.rows, n);
            e.run_spmm(&a, &segs[&e.variant.seg_len], &x, &mut y, &pool)
                .map_err(|err| format!("{}: {err:#}", e.label))?;
            assert_close(&y.data, &want.data, 1e-4, 1e-4)
                .map_err(|m| format!("{}: {m}", e.label))?;
        }
        Ok(())
    });
}

#[test]
fn serial_reduction_variants_are_bitwise_on_arbitrary_floats() {
    // SR variants keep the reference's per-row reduction order: row
    // tiling and merge-path span walking only re-chunk whole rows, so
    // under a serial pool (one span, CSR order) the output bits are the
    // reference's bits on arbitrary float data.
    run_prop("variants: sr bitwise", 32, |g| {
        let a = gen_matrix(g);
        let segs = layouts(&a);
        let n = *g.choose(&[1usize, 4, 7, 8, 32]);
        let x = DenseMatrix::from_vec(a.cols, n, g.vec_f32(a.cols * n));
        let mut want = DenseMatrix::zeros(a.rows, n);
        spmm_reference(&a, &x, &mut want);
        let serial = ThreadPool::serial();
        for e in registry().family_variants(SparseOp::Spmm, KernelKind::SrRs) {
            let mut y = DenseMatrix::zeros(a.rows, n);
            e.run_spmm(&a, &segs[&e.variant.seg_len], &x, &mut y, &serial)
                .map_err(|err| format!("{}: {err:#}", e.label))?;
            assert_bits(&y.data, &want.data, e.label)?;
        }
        Ok(())
    });
}

#[test]
fn integer_operands_make_every_spmm_variant_exact() {
    // On integer-valued A and X every partial sum is exactly
    // representable, so even the reassociating variants (WB segments at
    // every generated length, PR lanes, multi-worker merge-path carries)
    // must be bit-for-bit equal — any dropped or duplicated contribution
    // changes the result exactly.
    run_prop("variants: integer exactness", 24, |g| {
        let mut a = gen_matrix(g);
        for v in &mut a.values {
            *v = (((v.to_bits() >> 9) % 9) as i64 - 4) as f32;
        }
        let segs = layouts(&a);
        let n = *g.choose(&[1usize, 4, 8, 32]);
        let x = int_dense(a.cols, n, g.rng());
        let mut want = DenseMatrix::zeros(a.rows, n);
        spmm_reference(&a, &x, &mut want);
        let pool = ThreadPool::new(*g.choose(&[2usize, 4]));
        for e in registry().op_variants(SparseOp::Spmm) {
            let mut y = DenseMatrix::zeros(a.rows, n);
            e.run_spmm(&a, &segs[&e.variant.seg_len], &x, &mut y, &pool)
                .map_err(|err| format!("{}: {err:#}", e.label))?;
            assert_bits(&y.data, &want.data, &format!("{}/int", e.label))?;
        }
        Ok(())
    });
}

#[test]
fn every_sddmm_variant_is_bitwise_vs_the_reference() {
    // Each SDDMM output element is one dot product; kernels and reference
    // share a single canonical dot order per feature configuration, and
    // no variant splits a dot across workers — so every entry is exact.
    run_prop("variants: sddmm bitwise", 32, |g| {
        let a = gen_matrix(g);
        let segs = layouts(&a);
        let d = *g.choose(&[1usize, 7, 8, 9, 32, 33]);
        let u = DenseMatrix::from_vec(a.rows, d, g.vec_f32(a.rows * d));
        let v = DenseMatrix::from_vec(a.cols, d, g.vec_f32(a.cols * d));
        let mut want = vec![0f32; a.nnz()];
        sddmm_reference(&a, &u, &v, &mut want);
        let pool = ThreadPool::new(*g.choose(&[1usize, 2, 4]));
        for e in registry().op_variants(SparseOp::Sddmm) {
            let mut out = vec![0f32; a.nnz()];
            e.run_sddmm(&a, &segs[&e.variant.seg_len], &u, &v, &mut out, &pool)
                .map_err(|err| format!("{}: {err:#}", e.label))?;
            assert_bits(&out, &want, e.label)?;
        }
        Ok(())
    });
}

#[test]
fn misusing_an_entry_errors_instead_of_panicking() {
    let mut rng = ge_spmm::util::prng::Xoshiro256::seeded(7);
    let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(40, 30, 0.1, &mut rng));
    let pool = ThreadPool::serial();
    let reg = registry();

    // wrong op: an SDDMM entry refuses to run SpMM and vice versa
    let spmm = reg.canonical(SparseOp::Spmm, KernelKind::SrRs);
    let sddmm = reg.canonical(SparseOp::Sddmm, KernelKind::SrRs);
    let seg = SegmentedMatrix::from_csr(&a, spmm.variant.seg_len);
    let x = DenseMatrix::random(a.cols, 4, 1.0, &mut rng);
    let mut y = DenseMatrix::zeros(a.rows, 4);
    assert!(sddmm.run_spmm(&a, &seg, &x, &mut y, &pool).is_err());
    let u = DenseMatrix::random(a.rows, 4, 1.0, &mut rng);
    let v = DenseMatrix::random(a.cols, 4, 1.0, &mut rng);
    let mut out = vec![0f32; a.nnz()];
    assert!(spmm.run_sddmm(&a, &seg, &u, &v, &mut out, &pool).is_err());

    // mismatched layout: a balanced-family entry checks the segment length
    let wb = reg
        .op_variants(SparseOp::Spmm)
        .into_iter()
        .find(|e| e.variant.family == KernelKind::SrWb && e.variant.seg_len != 32)
        .expect("registry generates a non-default segment length");
    let wrong = SegmentedMatrix::from_csr(&a, 32);
    let mut y = DenseMatrix::zeros(a.rows, 4);
    assert!(wb.run_spmm(&a, &wrong, &x, &mut y, &pool).is_err());
}
