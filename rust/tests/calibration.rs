//! End-to-end measured calibration (ISSUE 4 tentpole): wallclock
//! profiling → threshold fit → persisted `HardwareProfile` → a serving
//! engine booted from it — plus the online selector demonstrably
//! shifting kernel choice under a skewed synthetic workload, observed
//! through the `Metrics` kernel/shard counters.

use ge_spmm::backend::NativeBackend;
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::features::MatrixFeatures;
use ge_spmm::kernels::dense::spmm_reference;
use ge_spmm::kernels::KernelKind;
use ge_spmm::selector::measured::{collect_samples, MeasureConfig};
use ge_spmm::selector::{calibrate, AdaptiveSelector, HardwareProfile, OnlineConfig};
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;
use std::time::Duration;

fn tiny_cfg() -> MeasureConfig {
    MeasureConfig {
        warmup: Duration::from_micros(200),
        measure: Duration::from_millis(2),
        min_iters: 2,
        max_iters: 16,
        seed: 5,
    }
}

fn suite() -> Vec<CsrMatrix> {
    let mut rng = Xoshiro256::seeded(61);
    vec![
        CsrMatrix::from_coo(&CooMatrix::random_uniform(200, 160, 0.05, &mut rng)),
        CsrMatrix::from_coo(&CooMatrix::random_uniform(120, 120, 0.15, &mut rng)),
    ]
}

#[test]
fn measured_calibration_to_profile_to_serving_engine() {
    // 1. wallclock profiles through the real backend
    let backend = NativeBackend::serial();
    let samples = collect_samples(&suite(), &[1, 16], &backend, &tiny_cfg()).unwrap();
    assert_eq!(samples.len(), 4);
    for s in &samples {
        for k in KernelKind::ALL {
            assert!(s.profile.time_of(k) > 0.0);
        }
    }
    // 2. the unchanged grid search fits thresholds on them
    let cal = calibrate::calibrate(&samples);
    assert!(cal.mean_loss >= 1.0);
    assert!(
        cal.mean_loss <= calibrate::selector_loss(&AdaptiveSelector::default(), &samples) + 1e-12
    );
    // 3. persist and reload as a hardware profile
    let dir = std::env::temp_dir().join("ge_spmm_calibration_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("profile.json");
    HardwareProfile::new(&cal, "measured", "native", samples.len(), &[1, 16])
        .save(&path)
        .unwrap();
    let loaded = HardwareProfile::load(&path).unwrap();
    assert_eq!(loaded.selector, cal.selector);
    assert_eq!(loaded.source, "measured");
    assert_eq!(loaded.samples, 4);
    std::fs::remove_file(&path).unwrap();
    // 4. a serving engine boots with the fitted thresholds at both grains
    let engine = SpmmEngine::serving_with_selector(16 << 20, 1_000_000, 2, loaded.selector);
    assert_eq!(engine.selector, loaded.selector);
    let a = suite().remove(0);
    let h = engine.register(a.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(62);
    let x = DenseMatrix::random(a.cols, 16, 1.0, &mut rng);
    let resp = engine.spmm(h, &x).unwrap();
    assert_eq!(
        resp.kernel,
        loaded.selector.select(&engine.features(h).unwrap(), 16)
    );
    let mut want = DenseMatrix::zeros(a.rows, 16);
    spmm_reference(&a, &x, &mut want);
    for (got, exp) in resp.y.data.iter().zip(&want.data) {
        assert!((got - exp).abs() <= 1e-4 + 1e-4 * exp.abs());
    }
}

/// Moderately skewed synthetic workload (cv_row ≈ 1.4, between the
/// refit grid's 1.0 candidate and the default T_cv = 1.5): the default
/// rule picks SR-RS at N = 32, and only an online refit can flip it.
fn skewed_matrix(rows: usize) -> CsrMatrix {
    let mut coo = CooMatrix::new(rows, 256);
    for r in 0..rows {
        if r % 12 == 0 {
            for c in 0..20 {
                coo.push(r, (r + 7 * c) % 256, 1.0);
            }
        } else {
            coo.push(r, r % 256, 1.0);
            coo.push(r, (r + 101) % 256, 1.0);
        }
    }
    CsrMatrix::from_coo(&coo)
}

#[test]
fn online_selector_shifts_kernel_choice_under_skewed_traffic() {
    let a = skewed_matrix(96);
    let f = MatrixFeatures::of(&a);
    assert!(f.cv_row > 1.05 && f.cv_row < 1.5, "cv {}", f.cv_row);

    // threshold 1 => requests take the sharded route; per-shard choices
    // land in the shard kernel counters
    let engine = SpmmEngine::serving_online(
        16 << 20,
        1,
        2,
        AdaptiveSelector::default(),
        OnlineConfig {
            explore_every: 0, // keep the baseline phase deterministic
            refit_every: 8,   // refit quickly under the injected stream
            min_observations: 2,
        },
    );
    let online = engine.online().unwrap();
    let h = engine.register(a.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(63);
    let x = DenseMatrix::random(256, 32, 1.0, &mut rng);

    // Phase 1: default thresholds — every shard runs SR-RS.
    for _ in 0..3 {
        engine.spmm(h, &x).unwrap();
    }
    let baseline = engine.metrics.shard_kernel_counts();
    assert_eq!(baseline[0], 6, "3 requests x 2 shards, all SR-RS: {baseline:?}");
    assert_eq!(baseline[1], 0);

    // Phase 2: the live stream reveals SR-WB is much cheaper for this
    // bucket (injected observations stand in for hardware where that is
    // true); the refit cadence fires within the stream.
    for _ in 0..8 {
        online.observe(&f, 32, KernelKind::SrRs, Duration::from_millis(6));
        online.observe(&f, 32, KernelKind::SrWb, Duration::from_micros(60));
    }
    assert!(online.refits() >= 1, "{}", online.summary());
    assert!(online.current().t_cv <= 1.0, "{}", online.summary());

    // Phase 3: the same traffic now runs SR-WB on every shard.
    for _ in 0..3 {
        let resp = engine.spmm(h, &x).unwrap();
        // results stay correct across the switch
        let mut want = DenseMatrix::zeros(a.rows, 32);
        spmm_reference(&a, &x, &mut want);
        for (got, exp) in resp.y.data.iter().zip(&want.data) {
            assert!((got - exp).abs() <= 1e-4 + 1e-4 * exp.abs());
        }
    }
    let shifted = engine.metrics.shard_kernel_counts();
    assert_eq!(shifted[0], baseline[0], "no further SR-RS shards: {shifted:?}");
    assert_eq!(shifted[1], 6, "all post-refit shards run SR-WB: {shifted:?}");
}

#[test]
fn exploration_feeds_both_siblings_through_live_traffic() {
    // With aggressive exploration every other request runs the sibling
    // kernel, so the cost table fills for both designs with no injected
    // observations at all — the precondition for honest refits.
    let a = skewed_matrix(48);
    let engine = SpmmEngine::serving_online(
        16 << 20,
        usize::MAX, // unsharded route: request-level decisions
        1,
        AdaptiveSelector::default(),
        OnlineConfig {
            explore_every: 2,
            refit_every: 0,
            min_observations: 1,
        },
    );
    let online = engine.online().unwrap();
    let h = engine.register(a).unwrap();
    let mut rng = Xoshiro256::seeded(64);
    let x = DenseMatrix::random(256, 32, 1.0, &mut rng);
    for _ in 0..6 {
        engine.spmm(h, &x).unwrap();
    }
    let counts = engine.metrics.kernel_counts();
    assert_eq!(counts[0], 3, "rule choice SR-RS: {counts:?}");
    assert_eq!(counts[1], 3, "explored sibling SR-WB: {counts:?}");
    assert_eq!(online.explorations(), 3);
    let metrics = online.metrics();
    let bucket = ge_spmm::selector::online::feature_bucket(&engine.features(h).unwrap(), 32);
    assert!(metrics.cost_observations(bucket, KernelKind::SrRs) >= 3);
    assert!(metrics.cost_observations(bucket, KernelKind::SrWb) >= 3);
}
