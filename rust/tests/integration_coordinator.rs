//! Integration: batcher and server request loop over real artifacts.

use ge_spmm::coordinator::batcher::Batcher;
use ge_spmm::coordinator::server::{serve, Request, ServerConfig, ServerReply};
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::kernels::dense::spmm_reference;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;
use std::path::Path;
use std::sync::mpsc;

fn artifact_dir() -> &'static Path {
    let p = Path::new("artifacts");
    assert!(
        p.join("manifest.json").exists(),
        "artifacts/manifest.json missing — run `make artifacts` first"
    );
    p
}

fn matrix(seed: u64) -> CsrMatrix {
    let mut rng = Xoshiro256::seeded(seed);
    CsrMatrix::from_coo(&CooMatrix::random_uniform(120, 120, 0.05, &mut rng))
}

#[test]
fn batcher_coalesces_and_results_match_unbatched() {
    let engine = SpmmEngine::new(artifact_dir()).unwrap();
    let a = matrix(2001);
    let h = engine.register(a.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(2002);

    let xs: Vec<DenseMatrix> = (0..4)
        .map(|_| DenseMatrix::random(120, 1, 1.0, &mut rng))
        .collect();

    let mut batcher = Batcher::new(&engine, 4);
    let mut results = Vec::new();
    for (i, x) in xs.iter().enumerate() {
        results.extend(batcher.submit(h, x.clone(), i as u64).unwrap().results);
    }
    // 4 columns = max_width → auto-flush happened
    assert_eq!(results.len(), 4);
    assert_eq!(batcher.pending(), 0);
    // exactly one artifact execution served all four requests
    assert_eq!(engine.metrics.requests(), 1);
    for r in &results {
        assert_eq!(r.batch_size, 4);
        let x = &xs[r.tag as usize];
        let mut want = DenseMatrix::zeros(120, 1);
        spmm_reference(&a, x, &mut want);
        let max_err = r
            .y
            .data
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-4, "tag {} err {max_err}", r.tag);
    }
}

#[test]
fn batcher_flush_all_handles_partial_batches() {
    let engine = SpmmEngine::new(artifact_dir()).unwrap();
    let a = matrix(2003);
    let h = engine.register(a.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(2004);
    let mut batcher = Batcher::new(&engine, 128);
    let x = DenseMatrix::random(120, 2, 1.0, &mut rng);
    assert!(batcher.submit(h, x.clone(), 7).unwrap().results.is_empty());
    assert_eq!(batcher.pending(), 1);
    let outcome = batcher.flush_all();
    assert!(outcome.failures.is_empty());
    let results = outcome.results;
    assert_eq!(results.len(), 1);
    assert_eq!(results[0].tag, 7);
    assert_eq!(results[0].y.cols, 2);
}

#[test]
fn server_loop_round_trips_requests() {
    // The PJRT client is !Send, so the engine (and `serve`) stay on this
    // thread; requesters live on a spawned producer thread — the same
    // topology a deployment would use (engine thread + I/O threads).
    let engine = SpmmEngine::new(artifact_dir()).unwrap();
    let a = matrix(2005);
    let h = engine.register(a.clone()).unwrap();

    let (tx, rx) = mpsc::channel::<Request>();
    let config = ServerConfig {
        max_width: 4,
        max_delay: std::time::Duration::from_millis(5),
        ..ServerConfig::default()
    };

    let producer = std::thread::spawn(move || {
        let mut rng = Xoshiro256::seeded(2006);
        let mut replies = Vec::new();
        // 5 single-column requests: 4 flush on width, 1 on deadline
        for tag in 0..5u64 {
            let (rtx, rrx) = mpsc::channel();
            let x = DenseMatrix::random(120, 1, 1.0, &mut rng);
            tx.send(Request::spmm(h, x, tag, rtx)).unwrap();
            replies.push(rrx);
        }
        drop(tx); // close the channel so the server loop exits when done
        for (tag, rrx) in replies.into_iter().enumerate() {
            match rrx
                .recv_timeout(std::time::Duration::from_secs(60))
                .unwrap()
            {
                ServerReply::Ok(r) => {
                    assert_eq!(r.tag, tag as u64);
                    assert_eq!(r.y.rows, 120);
                }
                ServerReply::Err(e) => panic!("request {tag} failed: {e}"),
            }
        }
    });

    serve(&engine, rx, config);
    producer.join().unwrap();
    assert!(engine.metrics.requests() >= 2, "batching should have merged");
}
