//! Degenerate-input and padding-poisoning regressions across all four
//! kernel designs (ISSUE 4 satellites):
//!
//! - `nnz == 0` matrices used to fabricate an all-padding segment whose
//!   row indices pointed at row 0, making the workload-balanced kernels
//!   carry a partial into `y[0]` — an out-of-bounds panic when
//!   `rows == 0` as well;
//! - format padding (ELL sentinel column 0, segment trailing-index
//!   repeats) must never be multiplied against X: the padded value is
//!   0.0, but `0.0 * NaN = NaN`, so a single non-finite dense entry
//!   would otherwise corrupt unrelated output rows.

use ge_spmm::backend::{NativeBackend, SpmmBackend};
use ge_spmm::kernels::dense::spmm_reference;
use ge_spmm::kernels::{pr_rs, pr_wb, sr_rs, sr_wb, KernelKind, WARP};
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix, EdgeDelta, EllMatrix, SegmentedMatrix};
use ge_spmm::util::proptest::{run_prop, Gen};
use ge_spmm::util::threadpool::ThreadPool;
use std::collections::BTreeMap;

/// Run one kernel directly (the code path `NativeBackend` guards with a
/// rows/cols check — direct callers get no such guard).
fn run_kernel(
    kind: KernelKind,
    a: &CsrMatrix,
    x: &DenseMatrix,
    y: &mut DenseMatrix,
    workers: usize,
) {
    let pool = ThreadPool::new(workers);
    let seg = SegmentedMatrix::from_csr(a, WARP);
    match kind {
        KernelKind::SrRs => sr_rs::spmm(a, x, y, &pool),
        KernelKind::SrWb => sr_wb::spmm(&seg, x, y, &pool),
        KernelKind::PrRs => pr_rs::spmm(a, x, y, &pool),
        KernelKind::PrWb => pr_wb::spmm(&seg, x, y, &pool),
    }
}

#[test]
fn nnz_zero_yields_zero_output_on_all_kernels() {
    // rows > 0, nnz == 0: every kernel must produce zeros (and not panic
    // on the previously-fabricated padding segment)
    let a = CsrMatrix::from_coo(&CooMatrix::new(5, 7));
    let x = DenseMatrix::from_vec(7, 3, vec![1.5; 21]);
    for kind in KernelKind::ALL {
        for workers in [1usize, 4] {
            let mut y = DenseMatrix::from_vec(5, 3, vec![9.0; 15]);
            run_kernel(kind, &a, &x, &mut y, workers);
            assert_eq!(y.data, vec![0.0; 15], "{kind:?} workers={workers}");
        }
    }
}

#[test]
fn rows_zero_is_a_no_op_on_all_kernels() {
    // rows == 0 (so nnz == 0 too): regression for the WB kernels' carry
    // into y[0..n], which is out of bounds here
    let a = CsrMatrix::from_coo(&CooMatrix::new(0, 7));
    let x = DenseMatrix::from_vec(7, 4, vec![2.0; 28]);
    for kind in KernelKind::ALL {
        for workers in [1usize, 3] {
            let mut y = DenseMatrix::zeros(0, 4);
            run_kernel(kind, &a, &x, &mut y, workers);
            assert!(y.data.is_empty(), "{kind:?} workers={workers}");
        }
    }
}

#[test]
fn degenerate_shapes_through_the_backend() {
    let backend = NativeBackend::default();
    for (rows, cols) in [(0usize, 4usize), (4, 0), (0, 0), (3, 3)] {
        let a = CsrMatrix::from_coo(&CooMatrix::new(rows, cols));
        let op = backend.prepare(&a).unwrap();
        let x = DenseMatrix::zeros(cols, 2);
        for kind in KernelKind::ALL {
            let exec = backend.execute(&op, &x, kind).unwrap();
            assert_eq!((exec.y.rows, exec.y.cols), (rows, 2), "{rows}x{cols} {kind:?}");
            assert!(exec.y.data.iter().all(|&v| v == 0.0));
        }
    }
}

/// Fixture: rows of very different lengths so the segmented layout has
/// trailing padding and the ELL layout pads every short row; no entry
/// references column 0, where X carries a NaN and an Inf.
fn nan_fixture() -> (CsrMatrix, DenseMatrix) {
    let mut coo = CooMatrix::new(40, 50);
    // one long row (crosses segment boundaries), many short ones
    for c in 1..45 {
        coo.push(7, c, 0.25 * c as f32);
    }
    for r in 0..40 {
        if r != 7 {
            coo.push(r, 1 + (r * 3) % 49, 1.0 + r as f32);
        }
    }
    let a = CsrMatrix::from_coo(&coo);
    let mut x = DenseMatrix::from_vec(50, 3, (0..150).map(|i| (i % 11) as f32 * 0.5).collect());
    // poison dense row 0 — reachable only through padding indices
    x.data[0] = f32::NAN;
    x.data[1] = f32::INFINITY;
    x.data[2] = f32::NEG_INFINITY;
    (a, x)
}

#[test]
fn padding_cannot_poison_outputs_on_any_kernel() {
    let (a, x) = nan_fixture();
    // the true product is finite everywhere: no real entry touches col 0
    let mut want = DenseMatrix::zeros(40, 3);
    spmm_reference(&a, &x, &mut want);
    assert!(want.data.iter().all(|v| v.is_finite()), "fixture broken");
    for kind in KernelKind::ALL {
        for workers in [1usize, 4] {
            let mut y = DenseMatrix::zeros(40, 3);
            run_kernel(kind, &a, &x, &mut y, workers);
            assert!(
                y.data.iter().all(|v| v.is_finite()),
                "{kind:?} workers={workers} leaked non-finite padding: {:?}",
                y.data.iter().take(6).collect::<Vec<_>>()
            );
            for (i, (got, exp)) in y.data.iter().zip(&want.data).enumerate() {
                assert!(
                    (got - exp).abs() <= 1e-4 + 1e-4 * exp.abs(),
                    "{kind:?} workers={workers} [{i}]: {got} vs {exp}"
                );
            }
        }
    }
}

#[test]
fn trailing_pad_column_poison_stays_local() {
    // Segment padding repeats the *last* real (row, col); poison that
    // column's dense row. Rows that genuinely reference the column go
    // NaN (reference agrees); every other row must stay finite — i.e.
    // padded slots contribute nothing, not even 0.0 * NaN.
    let (a, mut x) = nan_fixture();
    let seg = SegmentedMatrix::from_csr(&a, WARP);
    let pad_col = seg.col_idx[seg.nnz - 1] as usize;
    x.data[pad_col * 3..pad_col * 3 + 3].fill(f32::NAN);
    let mut want = DenseMatrix::zeros(40, 3);
    spmm_reference(&a, &x, &mut want);
    assert!(want.data.iter().any(|v| v.is_nan()), "fixture refs pad col");
    assert!(want.data.iter().any(|v| v.is_finite()), "fixture has clean rows");
    for kind in KernelKind::ALL {
        let mut y = DenseMatrix::zeros(40, 3);
        run_kernel(kind, &a, &x, &mut y, 4);
        for (i, (got, exp)) in y.data.iter().zip(&want.data).enumerate() {
            if exp.is_nan() {
                assert!(got.is_nan(), "{kind:?} [{i}]: dropped a real NaN");
            } else {
                assert!(
                    (got - exp).abs() <= 1e-4 + 1e-4 * exp.abs(),
                    "{kind:?} [{i}]: {got} vs {exp}"
                );
            }
        }
    }
}

#[test]
fn real_nan_entries_still_propagate() {
    // A matrix that *does* reference the poisoned column must propagate
    // the NaN — bounding by nnz must not silently drop real work.
    let mut coo = CooMatrix::new(3, 4);
    coo.push(1, 0, 1.0); // touches poisoned column 0
    coo.push(2, 3, 2.0);
    let a = CsrMatrix::from_coo(&coo);
    let mut x = DenseMatrix::from_vec(4, 2, vec![1.0; 8]);
    x.data[0] = f32::NAN;
    for kind in KernelKind::ALL {
        let mut y = DenseMatrix::zeros(3, 2);
        run_kernel(kind, &a, &x, &mut y, 2);
        assert!(y.at(1, 0).is_nan(), "{kind:?} dropped a real NaN");
        assert_eq!(y.at(2, 0), 2.0, "{kind:?}");
        assert_eq!(y.row(0), &[0.0, 0.0], "{kind:?}");
    }
}

/// Random base matrix plus its coordinate-map model (post-merge, so the
/// model reflects exactly what `from_coo` built).
fn random_base(g: &mut Gen) -> (CsrMatrix, BTreeMap<(usize, usize), f32>) {
    let rows = g.usize_in(1, 24);
    let cols = g.usize_in(1, 24);
    let mut coo = CooMatrix::new(rows, cols);
    for _ in 0..g.usize_in(0, 60) {
        let r = g.usize_in(0, rows);
        let c = g.usize_in(0, cols);
        coo.push(r, c, g.i64_in(-8, 8) as f32);
    }
    let csr = CsrMatrix::from_coo(&coo);
    let mut model = BTreeMap::new();
    for r in 0..rows {
        let (cs, vs) = csr.row(r);
        for (c, v) in cs.iter().zip(vs) {
            model.insert((r, *c as usize), *v);
        }
    }
    (csr, model)
}

#[test]
fn edge_delta_agrees_with_a_coo_rebuild_oracle() {
    // ISSUE-8 satellite: property-test `EdgeDelta` against the simplest
    // possible model — a coordinate map mutated by the pinned batch
    // semantics (deletes first, then last-wins inserts), rebuilt through
    // COO. Batches mix duplicate inserts, deletes of absent edges, and
    // (with some luck plus a directed nudge) rows shrinking to nnz == 0.
    run_prop("edge_delta_coo_oracle", 64, |g| {
        let (mut csr, mut model) = random_base(g);
        let (rows, cols) = (csr.rows, csr.cols);
        for _ in 0..g.usize_in(1, 5) {
            let mut delta = EdgeDelta::new();
            let mut dels = Vec::new();
            let mut ins = Vec::new();
            if g.chance(0.3) {
                // directed: drain one whole row so it shrinks to empty
                let r = g.usize_in(0, rows);
                for &c in csr.row(r).0 {
                    dels.push((r, c as usize));
                }
            }
            for _ in 0..g.usize_in(0, 12) {
                let r = g.usize_in(0, rows);
                let c = g.usize_in(0, cols);
                if g.chance(0.4) {
                    dels.push((r, c)); // often absent: must be a no-op
                } else {
                    ins.push(((r, c), g.i64_in(-8, 8) as f32)); // dups: last wins
                }
            }
            for &(r, c) in &dels {
                delta.delete(r, c);
            }
            for &((r, c), v) in &ins {
                delta.insert(r, c, v);
            }
            let before: Vec<(usize, usize)> = model.keys().copied().collect();
            let report = delta.apply(&mut csr);
            // model: deletes apply first, then inserts in batch order
            for (r, c) in &dels {
                model.remove(&(*r, *c));
            }
            for ((r, c), v) in &ins {
                model.insert((*r, *c), *v);
            }
            let after: Vec<(usize, usize)> = model.keys().copied().collect();
            // report counts come straight from the support diff
            let net_ins = after.iter().filter(|&k| !before.contains(k)).count();
            let net_del = before.iter().filter(|&k| !after.contains(k)).count();
            if report.inserted != net_ins || report.deleted != net_del {
                return Err(format!(
                    "report ({}, {}) vs support diff ({net_ins}, {net_del})",
                    report.inserted, report.deleted
                ));
            }
            if report.structural != (before != after) {
                return Err(format!(
                    "structural={} but support {}changed",
                    report.structural,
                    if before == after { "un" } else { "" }
                ));
            }
            // rebuild the oracle from the model and compare arrays
            // (epochs differ by construction: the oracle is epoch 0)
            let mut oracle = CooMatrix::new(rows, cols);
            for (&(r, c), &v) in &model {
                oracle.push(r, c, v);
            }
            let want = CsrMatrix::from_coo(&oracle);
            if csr.indptr != want.indptr || csr.indices != want.indices {
                return Err("patched structure != rebuilt structure".to_string());
            }
            if csr.values != want.values {
                return Err("patched values != rebuilt values".to_string());
            }
        }
        Ok(())
    });
}

#[test]
fn segmented_patch_values_agrees_with_a_rebuild() {
    // Value-only churn must keep the segmented layout's patch path
    // (`SegmentedMatrix::patch_values`, the `prepare_delta` fast path)
    // identical to a from-scratch re-cut of the mutated CSR.
    run_prop("segment_patch_oracle", 48, |g| {
        let (mut csr, model) = random_base(g);
        let mut seg = SegmentedMatrix::from_csr(&csr, WARP);
        let mut delta = EdgeDelta::new();
        let coords: Vec<(usize, usize)> = model.keys().copied().collect();
        if coords.is_empty() {
            return Ok(());
        }
        for _ in 0..g.usize_in(1, 10) {
            let &(r, c) = g.choose(&coords);
            delta.insert(r, c, g.i64_in(-8, 8) as f32);
        }
        let report = delta.apply(&mut csr);
        if report.structural {
            return Err("updates at existing coords must be value-only".into());
        }
        seg.patch_values(&csr.values);
        if seg != SegmentedMatrix::from_csr(&csr, WARP) {
            return Err("patched segments != re-cut segments".into());
        }
        Ok(())
    });
}

#[test]
fn segment_and_ell_padding_layouts_are_inert() {
    let (a, x) = nan_fixture();
    // segments: padded slots exist and repeat the last real (row, col)
    let seg = SegmentedMatrix::from_csr(&a, WARP);
    assert!(seg.num_segments * seg.seg_len > seg.nnz, "fixture has padding");
    for i in seg.nnz..seg.num_segments * seg.seg_len {
        assert_eq!(seg.values[i], 0.0);
        assert_eq!(seg.row_idx[i], seg.row_idx[seg.nnz - 1]);
    }
    // ELL: bounded gather stays finite despite sentinel column 0
    let ell = EllMatrix::from_csr(&a, 1, 1);
    assert!(ell.padding_ratio() > 1.0, "fixture has padding");
    let mut y = DenseMatrix::zeros(40, 3);
    ell.spmm_bounded(&x, &mut y);
    assert!(y.data.iter().all(|v| v.is_finite()));
    let mut want = DenseMatrix::zeros(40, 3);
    spmm_reference(&a, &x, &mut want);
    for (got, exp) in y.data.iter().zip(&want.data) {
        assert!((got - exp).abs() <= 1e-4 + 1e-4 * exp.abs());
    }
}
