//! Cross-kernel and cross-backend agreement through the `SpmmBackend`
//! trait.
//!
//! All four `KernelKind` designs, driven through `NativeBackend` via the
//! trait (prepare once, execute many), must match the dense reference on
//! uniform, R-MAT and banded matrices at N ∈ {1, 4, 32, 128}, including
//! empty-row and empty-matrix edge cases. This is the default-feature
//! stand-in for the artifact cross-check in `integration_runtime.rs`.
//!
//! The sharded tests additionally drive every kernel through
//! `ShardedBackend` and demand **bit-for-bit** equality with the
//! unsharded `NativeBackend` and the dense reference. That is checked on
//! integer-valued operands, where every f32 partial sum is exactly
//! representable: any dropped, duplicated, or misplaced row — the
//! failure modes of a partition/gather bug — changes the result exactly.
//! (On arbitrary float data the workload-balanced kernels' summation
//! grouping shifts with segment alignment, which sharding legitimately
//! changes, so float agreement is checked separately with tolerances.)

use ge_spmm::backend::{NativeBackend, SpmmBackend};
use ge_spmm::gen::banded::banded;
use ge_spmm::gen::powerlaw::PowerLawConfig;
use ge_spmm::gen::rmat::RmatConfig;
use ge_spmm::kernels::dense::spmm_reference;
use ge_spmm::kernels::KernelKind;
use ge_spmm::shard::ShardedBackend;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;
use ge_spmm::util::proptest::{assert_close, run_prop, Gen};
use ge_spmm::util::threadpool::ThreadPool;

mod common;
use common::int_dense;

/// The dense widths the artifact library is compiled at — the agreement
/// surface the paper's adaptive selector routes over.
const WIDTHS: [usize; 4] = [1, 4, 32, 128];

/// Prepare `csr` once, then check every kernel design against the dense
/// reference for the given operand.
fn check_all_kernels(
    backend: &NativeBackend,
    csr: &CsrMatrix,
    x: &DenseMatrix,
) -> Result<(), String> {
    let mut want = DenseMatrix::zeros(csr.rows, x.cols);
    spmm_reference(csr, x, &mut want);
    let op = backend.prepare(csr).map_err(|e| e.to_string())?;
    for kind in KernelKind::ALL {
        let exec = backend
            .execute(&op, x, kind)
            .map_err(|e| format!("{}: {e}", kind.label()))?;
        if (exec.y.rows, exec.y.cols) != (csr.rows, x.cols) {
            return Err(format!(
                "{}: output shape {}x{}, expected {}x{}",
                kind.label(),
                exec.y.rows,
                exec.y.cols,
                csr.rows,
                x.cols
            ));
        }
        assert_close(&exec.y.data, &want.data, 1e-4, 1e-4)
            .map_err(|m| format!("{}: {m}", kind.label()))?;
    }
    Ok(())
}

#[test]
fn uniform_matrices_agree_across_kernels() {
    run_prop("backend agreement: uniform", 24, |g| {
        let rows = g.dim() * 2;
        let cols = g.dim() * 2;
        let density = g.f64_in(0.02, 0.3);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(rows, cols, density, g.rng()));
        let n = *g.choose(&WIDTHS);
        let workers = *g.choose(&[1usize, 2, 4]);
        let backend = NativeBackend::new(ThreadPool::new(workers));
        let x = DenseMatrix::from_vec(cols, n, g.vec_f32(cols * n));
        check_all_kernels(&backend, &csr, &x)
    });
}

#[test]
fn rmat_matrices_agree_across_kernels() {
    run_prop("backend agreement: rmat", 10, |g| {
        let scale = g.usize_in(4, 9) as u32; // 16..256 vertices
        let edge_factor = g.f64_in(2.0, 8.0);
        let csr = CsrMatrix::from_coo(&RmatConfig::new(scale, edge_factor).generate(g.rng()));
        let n = *g.choose(&WIDTHS);
        let workers = *g.choose(&[1usize, 3]);
        let backend = NativeBackend::new(ThreadPool::new(workers));
        let x = DenseMatrix::from_vec(csr.cols, n, g.vec_f32(csr.cols * n));
        check_all_kernels(&backend, &csr, &x)
    });
}

#[test]
fn banded_matrices_agree_across_kernels() {
    run_prop("backend agreement: banded", 12, |g| {
        let dim = g.dim() * 4 + 4;
        let offsets: &[i64] = *g.choose(&[
            &[0i64][..],
            &[-1, 0, 1][..],
            &[-8, -1, 0, 1, 8][..],
        ]);
        let csr = CsrMatrix::from_coo(&banded(dim, offsets, g.rng()));
        let n = *g.choose(&WIDTHS);
        let backend = NativeBackend::new(ThreadPool::new(*g.choose(&[1usize, 2, 5])));
        let x = DenseMatrix::from_vec(csr.cols, n, g.vec_f32(csr.cols * n));
        check_all_kernels(&backend, &csr, &x)
    });
}

#[test]
fn empty_matrix_agrees_at_all_widths() {
    // Zero non-zeros: every kernel must produce an all-zero result.
    let csr = CsrMatrix::from_coo(&CooMatrix::new(64, 48));
    let backend = NativeBackend::new(ThreadPool::new(4));
    let mut rng = Xoshiro256::seeded(71);
    for n in WIDTHS {
        let x = DenseMatrix::random(48, n, 1.0, &mut rng);
        check_all_kernels(&backend, &csr, &x).unwrap();
        let op = backend.prepare(&csr).unwrap();
        let exec = backend.execute(&op, &x, KernelKind::PrWb).unwrap();
        assert!(exec.y.data.iter().all(|&v| v == 0.0));
    }
}

#[test]
fn empty_rows_agree_at_all_widths() {
    // Only every third row populated: row-split kernels see empty rows,
    // balanced kernels see segments skipping rows.
    let mut coo = CooMatrix::new(90, 60);
    let mut rng = Xoshiro256::seeded(72);
    for r in (0..90).step_by(3) {
        for _ in 0..4 {
            let c = (rng.below(60)) as usize;
            coo.push(r, c, rng.next_f32());
        }
    }
    let csr = CsrMatrix::from_coo(&coo);
    let backend = NativeBackend::new(ThreadPool::new(3));
    for n in WIDTHS {
        let x = DenseMatrix::random(60, n, 1.0, &mut rng);
        check_all_kernels(&backend, &csr, &x).unwrap();
    }
}

/// Integer-valued CSR (values in ±1..=4) with a mix of dense-ish, sparse
/// and empty rows — all f32 sums over it are exact.
fn int_matrix(rows: usize, cols: usize, rng: &mut Xoshiro256) -> CsrMatrix {
    let mut coo = CooMatrix::new(rows, cols);
    for r in 0..rows {
        let len = match rng.below(4) {
            0 => 0,                             // empty row
            1 => (rng.below(4) + 1) as usize,   // short row
            _ => (rng.below(17) + 4) as usize,  // longer row
        };
        for _ in 0..len.min(cols) {
            let sign = if rng.chance(0.5) { 1.0f32 } else { -1.0 };
            let v = (rng.below(4) + 1) as f32 * sign;
            coo.push(r, rng.below(cols as u64) as usize, v);
        }
    }
    CsrMatrix::from_coo(&coo)
}

/// Run every kernel through both an unsharded `NativeBackend` and
/// `ShardedBackend(k)` on integer operands; results must equal each other
/// and the dense reference bit-for-bit.
fn check_sharded_bit_for_bit(csr: &CsrMatrix, x: &DenseMatrix, k: usize) {
    let native = NativeBackend::new(ThreadPool::new(3));
    let sharded = ShardedBackend::new(k);
    let op_n = native.prepare(csr).unwrap();
    let op_s = sharded.prepare(csr).unwrap();
    let mut want = DenseMatrix::zeros(csr.rows, x.cols);
    spmm_reference(csr, x, &mut want);
    for kind in KernelKind::ALL {
        let yn = native.execute(&op_n, x, kind).unwrap().y;
        let ys = sharded.execute(&op_s, x, kind).unwrap().y;
        assert_eq!(
            yn.data,
            want.data,
            "native {} != reference ({}x{}, k={k})",
            kind.label(),
            csr.rows,
            csr.cols
        );
        assert_eq!(
            ys.data,
            yn.data,
            "sharded {} != native ({}x{}, k={k})",
            kind.label(),
            csr.rows,
            csr.cols
        );
    }
}

#[test]
fn sharded_all_kernels_bit_for_bit_vs_unsharded() {
    let mut rng = Xoshiro256::seeded(81);
    for k in [2usize, 4] {
        for (rows, cols) in [(97, 64), (160, 200), (33, 17)] {
            let csr = int_matrix(rows, cols, &mut rng);
            for n in WIDTHS {
                let x = int_dense(cols, n, &mut rng);
                check_sharded_bit_for_bit(&csr, &x, k);
            }
        }
    }
}

#[test]
fn sharded_bit_for_bit_edge_cases() {
    let mut rng = Xoshiro256::seeded(82);
    for k in [2usize, 4] {
        // empty matrix
        let empty = CsrMatrix::from_coo(&CooMatrix::new(50, 30));
        check_sharded_bit_for_bit(&empty, &int_dense(30, 4, &mut rng), k);
        // every third row populated, the rest empty
        let mut coo = CooMatrix::new(48, 36);
        for r in (0..48).step_by(3) {
            for j in 0..5u64 {
                coo.push(r, (r + j as usize * 7) % 36, (j + 1) as f32);
            }
        }
        let sparse_rows = CsrMatrix::from_coo(&coo);
        for n in WIDTHS {
            check_sharded_bit_for_bit(&sparse_rows, &int_dense(36, n, &mut rng), k);
        }
        // K > rows degenerates to one shard per row
        let tiny = int_matrix(3, 12, &mut rng);
        check_sharded_bit_for_bit(&tiny, &int_dense(12, 4, &mut rng), 7);
        // zero-rows matrix
        let zero_rows = CsrMatrix::from_coo(&CooMatrix::new(0, 9));
        check_sharded_bit_for_bit(&zero_rows, &int_dense(9, 4, &mut rng), k);
    }
}

/// Generate one matrix of the ISSUE-mandated families for the sharding
/// property: uniform, R-MAT, or power-law.
fn family_matrix(g: &mut Gen) -> CsrMatrix {
    match g.usize_in(0, 3) {
        0 => {
            let rows = g.dim() * 3;
            let cols = g.dim() * 3;
            let density = g.f64_in(0.02, 0.3);
            CsrMatrix::from_coo(&CooMatrix::random_uniform(rows, cols, density, g.rng()))
        }
        1 => {
            let scale = g.usize_in(4, 8) as u32;
            let ef = g.f64_in(2.0, 8.0);
            CsrMatrix::from_coo(&RmatConfig::new(scale, ef).generate(g.rng()))
        }
        _ => {
            let cfg = PowerLawConfig {
                rows: g.dim() * 6,
                cols: g.dim() * 6,
                alpha: g.f64_in(1.5, 2.8),
                min_row: 1,
                max_row: g.dim() * 6,
            };
            CsrMatrix::from_coo(&cfg.generate(g.rng()))
        }
    }
}

#[test]
fn sharded_matches_reference_across_k_property() {
    run_prop("sharded vs dense reference", 20, |g| {
        let csr = family_matrix(g);
        let k = *g.choose(&[1usize, 2, 3, 7, csr.rows + 1]);
        let n = *g.choose(&WIDTHS);
        let x = DenseMatrix::from_vec(csr.cols, n, g.vec_f32(csr.cols * n));
        let mut want = DenseMatrix::zeros(csr.rows, n);
        spmm_reference(&csr, &x, &mut want);
        let backend = ShardedBackend::new(k);
        let op = backend.prepare(&csr).map_err(|e| e.to_string())?;
        for kind in KernelKind::ALL {
            let exec = backend
                .execute(&op, &x, kind)
                .map_err(|e| format!("{} k={k}: {e}", kind.label()))?;
            assert_close(&exec.y.data, &want.data, 1e-4, 1e-4)
                .map_err(|m| format!("{} k={k} ({}x{}): {m}", kind.label(), csr.rows, csr.cols))?;
        }
        Ok(())
    });
}

#[test]
fn pathological_skew_agrees_at_all_widths() {
    // One row holds almost all non-zeros: the exact case the paper's
    // workload-balanced designs exist for.
    let mut coo = CooMatrix::new(40, 500);
    for c in 0..500 {
        coo.push(11, c, 0.002 * c as f32);
    }
    for r in 0..40 {
        coo.push(r, r, 1.0);
    }
    let csr = CsrMatrix::from_coo(&coo);
    let backend = NativeBackend::new(ThreadPool::new(6));
    let mut rng = Xoshiro256::seeded(73);
    for n in WIDTHS {
        let x = DenseMatrix::random(500, n, 1.0, &mut rng);
        check_all_kernels(&backend, &csr, &x).unwrap();
    }
}
