//! Cross-kernel agreement through the `SpmmBackend` trait.
//!
//! All four `KernelKind` designs, driven through `NativeBackend` via the
//! trait (prepare once, execute many), must match the dense reference on
//! uniform, R-MAT and banded matrices at N ∈ {1, 4, 32, 128}, including
//! empty-row and empty-matrix edge cases. This is the default-feature
//! stand-in for the artifact cross-check in `integration_runtime.rs`.

use ge_spmm::backend::{NativeBackend, SpmmBackend};
use ge_spmm::gen::banded::banded;
use ge_spmm::gen::rmat::RmatConfig;
use ge_spmm::kernels::dense::spmm_reference;
use ge_spmm::kernels::KernelKind;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;
use ge_spmm::util::proptest::{assert_close, run_prop};
use ge_spmm::util::threadpool::ThreadPool;

/// The dense widths the artifact library is compiled at — the agreement
/// surface the paper's adaptive selector routes over.
const WIDTHS: [usize; 4] = [1, 4, 32, 128];

/// Prepare `csr` once, then check every kernel design against the dense
/// reference for the given operand.
fn check_all_kernels(
    backend: &NativeBackend,
    csr: &CsrMatrix,
    x: &DenseMatrix,
) -> Result<(), String> {
    let mut want = DenseMatrix::zeros(csr.rows, x.cols);
    spmm_reference(csr, x, &mut want);
    let op = backend.prepare(csr).map_err(|e| e.to_string())?;
    for kind in KernelKind::ALL {
        let exec = backend
            .execute(&op, x, kind)
            .map_err(|e| format!("{}: {e}", kind.label()))?;
        if (exec.y.rows, exec.y.cols) != (csr.rows, x.cols) {
            return Err(format!(
                "{}: output shape {}x{}, expected {}x{}",
                kind.label(),
                exec.y.rows,
                exec.y.cols,
                csr.rows,
                x.cols
            ));
        }
        assert_close(&exec.y.data, &want.data, 1e-4, 1e-4)
            .map_err(|m| format!("{}: {m}", kind.label()))?;
    }
    Ok(())
}

#[test]
fn uniform_matrices_agree_across_kernels() {
    run_prop("backend agreement: uniform", 24, |g| {
        let rows = g.dim() * 2;
        let cols = g.dim() * 2;
        let density = g.f64_in(0.02, 0.3);
        let csr = CsrMatrix::from_coo(&CooMatrix::random_uniform(rows, cols, density, g.rng()));
        let n = *g.choose(&WIDTHS);
        let workers = *g.choose(&[1usize, 2, 4]);
        let backend = NativeBackend::new(ThreadPool::new(workers));
        let x = DenseMatrix::from_vec(cols, n, g.vec_f32(cols * n));
        check_all_kernels(&backend, &csr, &x)
    });
}

#[test]
fn rmat_matrices_agree_across_kernels() {
    run_prop("backend agreement: rmat", 10, |g| {
        let scale = g.usize_in(4, 9) as u32; // 16..256 vertices
        let edge_factor = g.f64_in(2.0, 8.0);
        let csr = CsrMatrix::from_coo(&RmatConfig::new(scale, edge_factor).generate(g.rng()));
        let n = *g.choose(&WIDTHS);
        let workers = *g.choose(&[1usize, 3]);
        let backend = NativeBackend::new(ThreadPool::new(workers));
        let x = DenseMatrix::from_vec(csr.cols, n, g.vec_f32(csr.cols * n));
        check_all_kernels(&backend, &csr, &x)
    });
}

#[test]
fn banded_matrices_agree_across_kernels() {
    run_prop("backend agreement: banded", 12, |g| {
        let dim = g.dim() * 4 + 4;
        let offsets: &[i64] = *g.choose(&[
            &[0i64][..],
            &[-1, 0, 1][..],
            &[-8, -1, 0, 1, 8][..],
        ]);
        let csr = CsrMatrix::from_coo(&banded(dim, offsets, g.rng()));
        let n = *g.choose(&WIDTHS);
        let backend = NativeBackend::new(ThreadPool::new(*g.choose(&[1usize, 2, 5])));
        let x = DenseMatrix::from_vec(csr.cols, n, g.vec_f32(csr.cols * n));
        check_all_kernels(&backend, &csr, &x)
    });
}

#[test]
fn empty_matrix_agrees_at_all_widths() {
    // Zero non-zeros: every kernel must produce an all-zero result.
    let csr = CsrMatrix::from_coo(&CooMatrix::new(64, 48));
    let backend = NativeBackend::new(ThreadPool::new(4));
    let mut rng = Xoshiro256::seeded(71);
    for n in WIDTHS {
        let x = DenseMatrix::random(48, n, 1.0, &mut rng);
        check_all_kernels(&backend, &csr, &x).unwrap();
        let op = backend.prepare(&csr).unwrap();
        let exec = backend.execute(&op, &x, KernelKind::PrWb).unwrap();
        assert!(exec.y.data.iter().all(|&v| v == 0.0));
    }
}

#[test]
fn empty_rows_agree_at_all_widths() {
    // Only every third row populated: row-split kernels see empty rows,
    // balanced kernels see segments skipping rows.
    let mut coo = CooMatrix::new(90, 60);
    let mut rng = Xoshiro256::seeded(72);
    for r in (0..90).step_by(3) {
        for _ in 0..4 {
            let c = (rng.below(60)) as usize;
            coo.push(r, c, rng.next_f32());
        }
    }
    let csr = CsrMatrix::from_coo(&coo);
    let backend = NativeBackend::new(ThreadPool::new(3));
    for n in WIDTHS {
        let x = DenseMatrix::random(60, n, 1.0, &mut rng);
        check_all_kernels(&backend, &csr, &x).unwrap();
    }
}

#[test]
fn pathological_skew_agrees_at_all_widths() {
    // One row holds almost all non-zeros: the exact case the paper's
    // workload-balanced designs exist for.
    let mut coo = CooMatrix::new(40, 500);
    for c in 0..500 {
        coo.push(11, c, 0.002 * c as f32);
    }
    for r in 0..40 {
        coo.push(r, r, 1.0);
    }
    let csr = CsrMatrix::from_coo(&coo);
    let backend = NativeBackend::new(ThreadPool::new(6));
    let mut rng = Xoshiro256::seeded(73);
    for n in WIDTHS {
        let x = DenseMatrix::random(500, n, 1.0, &mut rng);
        check_all_kernels(&backend, &csr, &x).unwrap();
    }
}
