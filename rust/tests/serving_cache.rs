//! Serving-layer integration on the native backend: the prepared-matrix
//! cache observed through the engine (fingerprint identity, hit/miss
//! counters, byte-budgeted LRU eviction), nnz-threshold routing to the
//! sharded path, the `ServerConfig` surface, admission control, and a
//! concurrent multi-worker smoke test whose results must match serial
//! unsharded execution bit-for-bit on integer operands (where every f32
//! partial sum is exact — the same discipline as `backend_agreement.rs`).

use ge_spmm::coordinator::batcher::Batcher;
use ge_spmm::coordinator::server::{Request, Server, ServerConfig, ServerReply};
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::kernels::dense::spmm_reference;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;
use std::sync::{mpsc, Arc};
use std::time::Duration;

mod common;
use common::int_dense;

/// Deterministic matrix with exactly 4 nnz in every row — fixed, known
/// `heap_bytes` across seeds, integer values (exact f32 sums).
fn fixed_size_matrix(rows: usize, cols: usize, seed: u64) -> CsrMatrix {
    let mut rng = Xoshiro256::seeded(seed);
    let mut coo = CooMatrix::new(rows, cols);
    for r in 0..rows {
        for j in 0..4u64 {
            let c = ((r as u64 * 31 + j * 7 + rng.below(3)) % cols as u64) as usize;
            coo.push(r, c, (rng.below(8) + 1) as f32);
        }
    }
    CsrMatrix::from_coo(&coo)
}

#[test]
fn fingerprint_identity_governs_cache_hits() {
    let engine = SpmmEngine::native().with_prepared_cache(64 << 20);
    let a = fixed_size_matrix(64, 48, 11);
    let same_content = fixed_size_matrix(64, 48, 11);
    let different = fixed_size_matrix(64, 48, 12);
    assert_eq!(a.fingerprint(), same_content.fingerprint());
    assert_ne!(a.fingerprint(), different.fingerprint());

    engine.register(a).unwrap();
    engine.register(same_content).unwrap(); // hit: same content, new instance
    engine.register(different).unwrap(); // miss: different content
    assert_eq!(engine.metrics.cache_hits(), 1);
    assert_eq!(engine.metrics.cache_misses(), 2);
    assert_eq!(engine.cache_usage().unwrap().0, 2);
}

#[test]
fn lru_eviction_respects_byte_budget_and_recency() {
    let a = fixed_size_matrix(64, 48, 21);
    let b = fixed_size_matrix(64, 48, 22);
    let c = fixed_size_matrix(64, 48, 23);
    let bytes = a.heap_bytes();
    assert_eq!(bytes, b.heap_bytes());
    // room for exactly two entries
    let engine = SpmmEngine::native().with_prepared_cache(2 * bytes);

    engine.register(a.clone()).unwrap(); // miss: {a}
    engine.register(b.clone()).unwrap(); // miss: {a, b}
    engine.register(a.clone()).unwrap(); // hit — a is now more recent than b
    engine.register(c.clone()).unwrap(); // miss: evicts b (LRU) → {a, c}
    assert_eq!(engine.metrics.cache_evictions(), 1);
    engine.register(b).unwrap(); // miss again: b was evicted; evicts a → {c, b}
    engine.register(c).unwrap(); // hit: c survived both evictions
    assert_eq!(engine.metrics.cache_hits(), 2);
    assert_eq!(engine.metrics.cache_misses(), 4);
    assert_eq!(engine.metrics.cache_evictions(), 2);
    assert_eq!(engine.cache_usage(), Some((2, 2 * bytes)));
}

#[test]
fn server_config_default_is_self_describing() {
    let config = ServerConfig::default();
    assert_eq!(config.max_width, 128);
    assert_eq!(config.max_delay, Duration::from_millis(2));
    assert_eq!(config.workers, 4);
    assert_eq!(config.max_queue, 1024);
}

#[test]
fn large_matrices_route_to_the_sharded_path() {
    let small = fixed_size_matrix(32, 40, 31); // 128 nnz
    let large = fixed_size_matrix(512, 40, 32); // 2048 nnz
    let engine = SpmmEngine::serving(64 << 20, small.nnz() + 1, 2);
    let hs = engine.register(small.clone()).unwrap();
    let hl = engine.register(large.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(33);
    let x = int_dense(40, 4, &mut rng);

    let resp = engine.spmm(hs, &x).unwrap();
    assert!(resp.artifact.starts_with("native/"), "{}", resp.artifact);
    assert_eq!(engine.metrics.shard_executions(), 0, "small stays unsharded");
    let mut want = DenseMatrix::zeros(32, 4);
    spmm_reference(&small, &x, &mut want);
    assert_eq!(resp.y.data, want.data, "bit-for-bit on integer operands");

    let resp = engine.spmm(hl, &x).unwrap();
    assert!(resp.artifact.starts_with("sharded(k="), "{}", resp.artifact);
    assert!(engine.metrics.shard_executions() >= 2, "fan-out recorded");
    let mut want = DenseMatrix::zeros(512, 4);
    spmm_reference(&large, &x, &mut want);
    assert_eq!(resp.y.data, want.data, "bit-for-bit on integer operands");
}

#[test]
fn content_identical_handles_share_a_batch() {
    let engine = SpmmEngine::native().with_prepared_cache(64 << 20);
    let m = fixed_size_matrix(40, 30, 51);
    let h1 = engine.register(m.clone()).unwrap();
    let h2 = engine.register(m.clone()).unwrap();
    assert_eq!(
        engine.batch_key(h1).unwrap(),
        engine.batch_key(h2).unwrap(),
        "cached handles share the registration identity"
    );
    let mut rng = Xoshiro256::seeded(52);
    let x1 = int_dense(30, 1, &mut rng);
    let x2 = int_dense(30, 1, &mut rng);
    let mut want1 = DenseMatrix::zeros(40, 1);
    let mut want2 = DenseMatrix::zeros(40, 1);
    spmm_reference(&m, &x1, &mut want1);
    spmm_reference(&m, &x2, &mut want2);
    let mut batcher = Batcher::new(&engine, 2);
    assert!(batcher.submit(h1, x1, 1).unwrap().results.is_empty());
    let out = batcher.submit(h2, x2, 2).unwrap(); // width 2 → auto-flush
    assert!(out.failures.is_empty());
    assert_eq!(out.results.len(), 2);
    // one engine execution served both handles' requests
    assert_eq!(engine.metrics.requests(), 1);
    for r in &out.results {
        assert_eq!(r.batch_size, 2);
        let want = if r.tag == 1 { &want1 } else { &want2 };
        assert_eq!(r.y.data, want.data);
    }
}

#[test]
fn duplicate_in_flight_tags_are_rejected() {
    let engine = Arc::new(SpmmEngine::native().with_prepared_cache(64 << 20));
    let h = engine.register(fixed_size_matrix(24, 20, 61)).unwrap();
    // long deadline + unreachable width: the first request stays in
    // flight, so the second submission with the same tag must collide
    let server = Server::start(
        engine.clone(),
        ServerConfig {
            max_width: 1000,
            max_delay: Duration::from_millis(600),
            workers: 1,
            max_queue: 16,
        },
    );
    let mut rng = Xoshiro256::seeded(62);
    let (tx1, rx1) = mpsc::channel();
    let (tx2, rx2) = mpsc::channel();
    for reply in [tx1, tx2] {
        assert!(server.submit(Request::spmm(h, int_dense(20, 1, &mut rng), 7, reply)));
    }
    match rx2.recv_timeout(Duration::from_secs(30)).unwrap() {
        ServerReply::Err(e) => assert!(e.contains("duplicate"), "{e}"),
        ServerReply::Ok(_) => panic!("colliding tag must be rejected"),
    }
    match rx1.recv_timeout(Duration::from_secs(30)).unwrap() {
        ServerReply::Ok(r) => assert_eq!(r.tag, 7),
        ServerReply::Err(e) => panic!("first request must still deliver: {e}"),
    }
    assert_eq!(server.in_flight(), 0, "the rejected duplicate released its slot");
    server.shutdown();
}

#[test]
fn admission_bound_rejects_and_recovers() {
    let engine = Arc::new(SpmmEngine::native().with_prepared_cache(64 << 20));
    let h = engine.register(fixed_size_matrix(48, 36, 41)).unwrap();
    // One worker, a queue of 2, and a batcher that cannot flush on width:
    // admitted requests stay in flight until the (long) deadline, so the
    // 3rd and 4th submissions deterministically hit the admission bound.
    let server = Server::start(
        engine.clone(),
        ServerConfig {
            max_width: 1000,
            max_delay: Duration::from_millis(600),
            workers: 1,
            max_queue: 2,
        },
    );
    let mut rng = Xoshiro256::seeded(42);
    let mut replies = Vec::new();
    let mut accepted = 0;
    for tag in 0..4u64 {
        let (rtx, rrx) = mpsc::channel();
        if server.submit(Request::spmm(h, int_dense(36, 1, &mut rng), tag, rtx)) {
            accepted += 1;
        }
        replies.push(rrx);
    }
    assert_eq!(accepted, 2);
    assert_eq!(server.in_flight(), 2);
    let (mut ok, mut rejected) = (0, 0);
    for rrx in replies {
        match rrx.recv_timeout(Duration::from_secs(30)).unwrap() {
            ServerReply::Ok(_) => ok += 1,
            ServerReply::Err(e) => {
                assert!(e.contains("capacity"), "{e}");
                rejected += 1;
            }
        }
    }
    assert_eq!((ok, rejected), (2, 2));
    assert_eq!(engine.metrics.rejections(), 2);
    assert_eq!(engine.metrics.max_queue_depth(), 2);
    // the deadline flush released the admitted slots
    assert_eq!(server.in_flight(), 0);
    server.shutdown();
    assert_eq!(engine.metrics.errors(), 0);
}

#[test]
fn mutated_matrix_misses_the_cache() {
    use ge_spmm::sparse::EdgeDelta;
    let engine = SpmmEngine::native().with_prepared_cache(64 << 20);
    let a = fixed_size_matrix(48, 36, 71);
    let h = engine.register(a.clone()).unwrap();
    let key0 = engine.batch_key(h).unwrap();
    let mut delta = EdgeDelta::new();
    delta.insert(0, a.row(0).0[0] as usize, 17.0);
    let out = engine.apply_delta(h, &delta).unwrap();
    assert!(out.patched && !out.report.structural);
    // the epoch bump rotates the batch key: the serving layer can no
    // longer co-batch this handle with pre-mutation traffic, and the
    // stale prepared-cache entry is gone (one fresh entry replaces it)
    assert_ne!(engine.batch_key(h).unwrap(), key0);
    assert_eq!(engine.cache_usage().unwrap().0, 1);
    // the pre-mutation content misses
    engine.register(a.clone()).unwrap();
    assert_eq!(engine.metrics.cache_hits(), 0);
    assert_eq!(engine.metrics.cache_misses(), 2);
    // ...and so does an epoch-0 rebuild of the post-mutation content:
    // the fingerprint folds the epoch, so only the mutated registration
    // itself owns its cache identity
    let mut m = a;
    delta.apply(&mut m);
    let rebuilt = CsrMatrix::from_parts(
        m.rows,
        m.cols,
        m.indptr.clone(),
        m.indices.clone(),
        m.values.clone(),
    );
    assert_ne!(rebuilt.fingerprint(), engine.batch_key(h).unwrap());
    engine.register(rebuilt).unwrap();
    assert_eq!(engine.metrics.cache_hits(), 0);
    assert_eq!(engine.metrics.cache_misses(), 3);
}

#[test]
fn concurrent_reader_never_observes_half_patched_state() {
    use ge_spmm::kernels::KernelKind;
    use ge_spmm::sparse::EdgeDelta;
    use std::sync::atomic::{AtomicBool, Ordering};
    const BATCHES: usize = 40;
    const ROWS: usize = 96;
    const COLS: usize = 64;

    let engine = Arc::new(SpmmEngine::native().with_prepared_cache(64 << 20));
    let a = fixed_size_matrix(ROWS, COLS, 81);
    let h = engine.register(a.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(82);
    let x = int_dense(COLS, 3, &mut rng);

    // Value-only batches keep the structure fixed, so every epoch's
    // ground truth is computable up front: truths[e] = A_e · X.
    let mut m = a;
    let mut deltas = Vec::new();
    let mut truths = Vec::new();
    let mut want = DenseMatrix::zeros(ROWS, 3);
    spmm_reference(&m, &x, &mut want);
    truths.push(want.data);
    for _ in 0..BATCHES {
        let mut d = EdgeDelta::new();
        for _ in 0..6 {
            let r = rng.below(ROWS as u64) as usize;
            let (cols, _) = m.row(r);
            if cols.is_empty() {
                continue;
            }
            let c = cols[rng.below(cols.len() as u64) as usize] as usize;
            d.insert(r, c, (rng.below(9) as i64 - 4) as f32);
        }
        d.apply(&mut m);
        let mut want = DenseMatrix::zeros(ROWS, 3);
        spmm_reference(&m, &x, &mut want);
        truths.push(want.data);
        deltas.push(d);
    }

    // Readers hammer the handle while the writer flushes batch after
    // batch. The swap is one Arc replacement under the handle-map lock:
    // every read must equal SOME epoch's truth exactly — a half-patched
    // prepared state would produce a vector matching no epoch.
    let stop = AtomicBool::new(false);
    std::thread::scope(|s| {
        for _ in 0..3 {
            let engine = engine.clone();
            let (x, truths, stop) = (&x, &truths, &stop);
            s.spawn(move || {
                while !stop.load(Ordering::Acquire) {
                    let y = engine.spmm_with(h, x, KernelKind::SrRs).unwrap().y;
                    assert!(
                        truths.iter().any(|t| *t == y.data),
                        "mid-flush read matches no epoch's ground truth"
                    );
                }
            });
        }
        for d in &deltas {
            let out = engine.apply_delta(h, d).unwrap();
            assert!(out.patched, "value-only churn patches in place");
        }
        stop.store(true, Ordering::Release);
    });
    // quiesced: the final state is exactly the last epoch
    let y = engine.spmm_with(h, &x, KernelKind::SrRs).unwrap().y;
    assert_eq!(y.data, *truths.last().unwrap());
    assert_eq!(engine.metrics.errors(), 0);
}

#[test]
fn concurrent_server_matches_serial_bit_for_bit() {
    const PRODUCERS: usize = 4;
    const MATRICES: usize = 3;
    const REQUESTS: usize = 24;

    let engine = Arc::new(SpmmEngine::native().with_prepared_cache(64 << 20));
    // warm the cache once from this thread, so every per-producer
    // registration below is deterministically a hit
    for i in 0..MATRICES {
        engine
            .register(fixed_size_matrix(60 + 20 * i, 50, 100 + i as u64))
            .unwrap();
    }
    assert_eq!(engine.metrics.cache_misses(), MATRICES as u64);
    let server = Server::start(
        engine.clone(),
        ServerConfig {
            max_width: 8,
            max_delay: Duration::from_millis(2),
            workers: 3,
            max_queue: 4096,
        },
    );

    std::thread::scope(|s| {
        for p in 0..PRODUCERS {
            let engine = engine.clone();
            let server = &server;
            s.spawn(move || {
                // every producer registers the same matrix mix: the first
                // landing prepares, the rest hit the cache
                let mats: Vec<CsrMatrix> = (0..MATRICES)
                    .map(|i| fixed_size_matrix(60 + 20 * i, 50, 100 + i as u64))
                    .collect();
                let handles: Vec<_> = mats
                    .iter()
                    .map(|m| engine.register(m.clone()).unwrap())
                    .collect();
                let mut rng = Xoshiro256::seeded(4200 + p as u64);
                let mut pending = Vec::new();
                for r in 0..REQUESTS {
                    let i = r % MATRICES;
                    let n = 1 + r % 3;
                    let x = int_dense(50, n, &mut rng);
                    // serial unsharded ground truth, exact on int operands
                    let mut want = DenseMatrix::zeros(mats[i].rows, n);
                    spmm_reference(&mats[i], &x, &mut want);
                    let tag = (p * REQUESTS + r) as u64;
                    let (rtx, rrx) = mpsc::channel();
                    assert!(server.submit(Request::spmm(handles[i], x, tag, rtx)));
                    pending.push((tag, want, rrx));
                }
                for (tag, want, rrx) in pending {
                    match rrx.recv_timeout(Duration::from_secs(60)).unwrap() {
                        ServerReply::Ok(r) => {
                            assert_eq!(r.tag, tag);
                            assert_eq!(
                                r.y.data, want.data,
                                "tag {tag}: batched concurrent result differs from serial"
                            );
                        }
                        ServerReply::Err(e) => panic!("request {tag} failed: {e}"),
                    }
                }
            });
        }
    });
    server.shutdown();

    // every execution accounted for, none failed, nothing left in flight
    assert_eq!(engine.metrics.errors(), 0);
    assert_eq!(engine.metrics.rejections(), 0);
    let requests = engine.metrics.requests();
    assert!((1..=(PRODUCERS * REQUESTS) as u64).contains(&requests));
    // cache: the warmup paid the only prepares; every producer-side
    // registration hit the shared prepared state
    assert_eq!(engine.metrics.cache_misses(), MATRICES as u64);
    assert_eq!(engine.metrics.cache_hits(), (PRODUCERS * MATRICES) as u64);
    assert_eq!(engine.cache_usage().unwrap().0, MATRICES);
}
