//! SIMD agreement surface (ISSUE 6): the vectorized inner loops must not
//! change results.
//!
//! The invariants pinned here hold **in every feature configuration** —
//! default scalar build, `--features simd` (stable 8-lane tiles), and
//! `--features portable_simd` (nightly `std::simd`) — because CI runs
//! this binary under each one:
//!
//! - SpMM's inner `j` loop is elementwise (the reduction axis is `nnz`,
//!   not `j`), so tiling it reassociates nothing: the fixed-reduction-
//!   order kernels (`sr_rs`, serial merge-path) are **bit-for-bit** equal
//!   to the dense reference on arbitrary float data, vectorized or not.
//! - All four SpMM designs plus merge-path agree with the reference
//!   within float tolerance under parallel pools, and exactly on
//!   integer-valued operands (every partial sum exactly representable).
//! - All four SDDMM designs are **bit-for-bit** equal to
//!   `sddmm_reference` in every configuration, because kernels and
//!   reference share one canonical dot order per configuration (see
//!   `sddmm` module docs, "Canonical dot under `simd`").
//! - The `vec8` tiled backends match the scalar backends bitwise for the
//!   elementwise primitives and within 4 ULP for the blocked dot.
//! - The aligned-operand entry point (`sr_rs::spmm_aligned` over
//!   `AlignedDense`) is bit-for-bit equal to the packed path.

use ge_spmm::gen::banded::banded;
use ge_spmm::gen::powerlaw::PowerLawConfig;
use ge_spmm::gen::rmat::RmatConfig;
use ge_spmm::kernels::dense::{sddmm_reference, spmm_reference};
use ge_spmm::kernels::{merge_path, pr_rs, pr_wb, sr_rs, sr_wb, vec8, KernelKind, WARP};
use ge_spmm::sddmm;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix, SegmentedMatrix};
use ge_spmm::util::proptest::{assert_close, run_prop, Gen};
use ge_spmm::util::threadpool::ThreadPool;

mod common;
use common::int_dense;

/// One matrix from each generator family the selector is tested over:
/// uniform, power-law (heavy tail), banded, R-MAT.
fn gen_matrix(g: &mut Gen) -> CsrMatrix {
    let family = *g.choose(&[0usize, 1, 2, 3]);
    let coo = match family {
        0 => {
            let rows = g.dim() * 2 + 1;
            let cols = g.dim() * 2 + 1;
            let density = g.f64_in(0.02, 0.3);
            CooMatrix::random_uniform(rows, cols, density, g.rng())
        }
        1 => {
            let rows = g.dim() * 4 + 8;
            PowerLawConfig {
                rows,
                cols: rows,
                alpha: 1.7,
                min_row: 1,
                max_row: (rows / 2).max(2),
            }
            .generate(g.rng())
        }
        2 => {
            let n = g.dim() * 2 + 2;
            banded(n, &[-3, -1, 0, 1, 5], g.rng())
        }
        _ => RmatConfig::new(5, 4.0).generate(g.rng()),
    };
    CsrMatrix::from_coo(&coo)
}

/// Assert bit-for-bit equality with a labelled first-divergence message.
fn assert_bits(actual: &[f32], expect: &[f32], what: &str) -> Result<(), String> {
    if actual.len() != expect.len() {
        return Err(format!("{what}: length {} vs {}", actual.len(), expect.len()));
    }
    for (i, (a, e)) in actual.iter().zip(expect).enumerate() {
        if a.to_bits() != e.to_bits() {
            return Err(format!("{what}: first divergence at {i}: {a:e} vs {e:e}"));
        }
    }
    Ok(())
}

/// See `vec8` unit tests: f32 bits on a monotone integer line.
fn ulp_diff(a: f32, b: f32) -> u64 {
    fn monotone(x: f32) -> i64 {
        let b = x.to_bits();
        if b & 0x8000_0000 != 0 {
            -((b & 0x7fff_ffff) as i64)
        } else {
            b as i64
        }
    }
    (monotone(a) - monotone(b)).unsigned_abs()
}

#[test]
fn fixed_order_kernels_bitwise_vs_reference() {
    run_prop("simd: fixed-order bitwise", 32, |g| {
        let a = gen_matrix(g);
        let n = *g.choose(&[1usize, 4, 7, 8, 9, 32, 33]);
        let x = DenseMatrix::from_vec(a.cols, n, g.vec_f32(a.cols * n));
        let mut want = DenseMatrix::zeros(a.rows, n);
        spmm_reference(&a, &x, &mut want);
        let serial = ThreadPool::serial();
        let parallel = ThreadPool::new(*g.choose(&[2usize, 3, 4]));

        // sr_rs keeps the reference's exact reduction order even when the
        // row range is split across workers (rows are never split).
        for (pool, tag) in [(&serial, "serial"), (&parallel, "parallel")] {
            let mut y = DenseMatrix::zeros(a.rows, n);
            sr_rs::spmm(&a, &x, &mut y, pool);
            assert_bits(&y.data, &want.data, &format!("sr_rs/{tag}"))?;
        }
        // aligned operand: padded stride, identical row semantics
        let xa = x.to_aligned();
        let mut y = DenseMatrix::zeros(a.rows, n);
        sr_rs::spmm_aligned(&a, &xa, &mut y, &parallel);
        assert_bits(&y.data, &want.data, "spmm_aligned")?;
        // merge-path with one worker is one span walked in CSR order
        let mut y = DenseMatrix::zeros(a.rows, n);
        merge_path::spmm(&a, &x, &mut y, &serial);
        assert_bits(&y.data, &want.data, "merge_path/serial")?;
        Ok(())
    });
}

#[test]
fn all_spmm_designs_agree_across_generators() {
    run_prop("simd: all designs vs reference", 32, |g| {
        let a = gen_matrix(g);
        let seg = SegmentedMatrix::from_csr(&a, WARP);
        let n = *g.choose(&[1usize, 4, 8, 32, 33]);
        let x = DenseMatrix::from_vec(a.cols, n, g.vec_f32(a.cols * n));
        let mut want = DenseMatrix::zeros(a.rows, n);
        spmm_reference(&a, &x, &mut want);
        let pool = ThreadPool::new(*g.choose(&[1usize, 2, 4]));

        let run = |name: &str, f: &mut dyn FnMut(&mut DenseMatrix)| {
            let mut y = DenseMatrix::zeros(a.rows, n);
            f(&mut y);
            assert_close(&y.data, &want.data, 1e-4, 1e-4).map_err(|m| format!("{name}: {m}"))
        };
        run("sr_rs", &mut |y| sr_rs::spmm(&a, &x, y, &pool))?;
        run("sr_wb", &mut |y| sr_wb::spmm(&seg, &x, y, &pool))?;
        run("pr_rs", &mut |y| pr_rs::spmm(&a, &x, y, &pool))?;
        run("pr_wb", &mut |y| pr_wb::spmm(&seg, &x, y, &pool))?;
        run("merge_path", &mut |y| merge_path::spmm(&a, &x, y, &pool))?;
        Ok(())
    });
}

#[test]
fn integer_operands_make_every_design_exact() {
    // On integer-valued A and X every partial sum is exactly
    // representable, so even the reassociating designs (WB segments, PR
    // lanes, multi-worker merge-path carries) must be bit-for-bit equal —
    // any dropped or duplicated contribution changes the result exactly.
    run_prop("simd: integer exactness", 24, |g| {
        let mut a = gen_matrix(g);
        for v in &mut a.values {
            *v = (((v.to_bits() >> 9) % 9) as i64 - 4) as f32;
        }
        let seg = SegmentedMatrix::from_csr(&a, WARP);
        let n = *g.choose(&[1usize, 4, 8, 32]);
        let x = int_dense(a.cols, n, g.rng());
        let mut want = DenseMatrix::zeros(a.rows, n);
        spmm_reference(&a, &x, &mut want);
        let pool = ThreadPool::new(*g.choose(&[2usize, 4]));

        let mut y = DenseMatrix::zeros(a.rows, n);
        sr_wb::spmm(&seg, &x, &mut y, &pool);
        assert_bits(&y.data, &want.data, "sr_wb/int")?;
        let mut y = DenseMatrix::zeros(a.rows, n);
        pr_rs::spmm(&a, &x, &mut y, &pool);
        assert_bits(&y.data, &want.data, "pr_rs/int")?;
        let mut y = DenseMatrix::zeros(a.rows, n);
        pr_wb::spmm(&seg, &x, &mut y, &pool);
        assert_bits(&y.data, &want.data, "pr_wb/int")?;
        let mut y = DenseMatrix::zeros(a.rows, n);
        merge_path::spmm(&a, &x, &mut y, &pool);
        assert_bits(&y.data, &want.data, "merge_path/int")?;
        Ok(())
    });
}

#[test]
fn sddmm_designs_bitwise_vs_reference_in_this_configuration() {
    run_prop("simd: sddmm bitwise", 32, |g| {
        let a = gen_matrix(g);
        let seg = SegmentedMatrix::from_csr(&a, WARP);
        let d = *g.choose(&[1usize, 7, 8, 9, 32, 33]);
        let u = DenseMatrix::from_vec(a.rows, d, g.vec_f32(a.rows * d));
        let v = DenseMatrix::from_vec(a.cols, d, g.vec_f32(a.cols * d));
        let mut want = vec![0f32; a.nnz()];
        sddmm_reference(&a, &u, &v, &mut want);
        let pool = ThreadPool::new(*g.choose(&[1usize, 2, 4]));
        for kind in KernelKind::ALL {
            let mut out = vec![0f32; a.nnz()];
            sddmm::run(kind, &a, &seg, &u, &v, &mut out, &pool);
            assert_bits(&out, &want, &format!("sddmm/{}", kind.label()))?;
        }
        Ok(())
    });
}

#[test]
fn vec8_tiled_backends_match_scalar() {
    run_prop("simd: vec8 tiled vs scalar", 48, |g| {
        let len = g.usize_in(0, 100);
        let x = g.vec_f32(len);
        let base = g.vec_f32(len);
        let a = g.value();

        let (mut s, mut t) = (base.clone(), base.clone());
        vec8::axpy_scalar(&mut s, a, &x);
        vec8::axpy_tiled(&mut t, a, &x);
        assert_bits(&t, &s, "axpy")?;

        let (mut s, mut t) = (base.clone(), base.clone());
        vec8::add_assign_scalar(&mut s, &x);
        vec8::add_assign_tiled(&mut t, &x);
        assert_bits(&t, &s, "add_assign")?;

        let (mut s, mut t) = (vec![0f32; len], vec![0f32; len]);
        vec8::mul_store_scalar(&mut s, a, &x);
        vec8::mul_store_tiled(&mut t, a, &x);
        assert_bits(&t, &s, "mul_store")?;

        let seq = vec8::dot_scalar(&base, &x);
        let blk = vec8::dot_blocked(&base, &x);
        let d = ulp_diff(seq, blk);
        if d > 4 {
            return Err(format!("dot orders {d} ulps apart: {seq:e} vs {blk:e}"));
        }
        // the public entry points resolve to exactly one backend per
        // feature configuration — pin which one
        let want = if cfg!(feature = "simd") { blk } else { seq };
        if vec8::dot(&base, &x).to_bits() != want.to_bits() {
            return Err("public dot does not match its configured backend".into());
        }
        Ok(())
    });
}

#[test]
fn merge_path_partition_covers_everything_once() {
    run_prop("simd: merge-path partition", 32, |g| {
        let a = gen_matrix(g);
        let parts = g.usize_in(1, 9);
        let splits = merge_path::partition(&a, parts);
        if splits.first() != Some(&(0, 0)) {
            return Err(format!("first split {:?}", splits.first()));
        }
        if splits.last() != Some(&(a.rows, a.nnz())) {
            return Err(format!("last split {:?}", splits.last()));
        }
        for w in splits.windows(2) {
            let ((r0, k0), (r1, k1)) = (w[0], w[1]);
            if r1 < r0 || k1 < k0 {
                return Err(format!("non-monotone splits {:?} -> {:?}", w[0], w[1]));
            }
            // a worker's span is its merge-path distance: rows + nnz
            if (r1 - r0) + (k1 - k0) > a.rows + a.nnz() {
                return Err("span exceeds total work".into());
            }
        }
        Ok(())
    });
}
