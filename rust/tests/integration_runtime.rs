//! Integration: PJRT runtime executing real AOT artifacts.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).

use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::kernels::dense::spmm_reference;
use ge_spmm::kernels::KernelKind;
use ge_spmm::runtime::Engine;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;
use std::path::Path;

fn artifact_dir() -> &'static Path {
    let p = Path::new("artifacts");
    assert!(
        p.join("manifest.json").exists(),
        "artifacts/manifest.json missing — run `make artifacts` first"
    );
    p
}

fn small_matrix(rows: usize, cols: usize, density: f64, seed: u64) -> CsrMatrix {
    let mut rng = Xoshiro256::seeded(seed);
    CsrMatrix::from_coo(&CooMatrix::random_uniform(rows, cols, density, &mut rng))
}

#[test]
fn manifest_loads_and_lists_all_variants() {
    let engine = Engine::new(artifact_dir()).unwrap();
    assert_eq!(engine.platform(), "cpu");
    for v in ["sr_rs", "sr_wb", "pr_rs", "pr_wb"] {
        let variants = engine.manifest.spmm_variants(v);
        assert!(
            variants.len() >= 4,
            "expected ≥4 {v} artifacts, got {}",
            variants.len()
        );
    }
    assert!(engine.manifest.by_name("gcn_step").is_some());
    assert!(engine.manifest.by_name("gcn_fwd").is_some());
}

#[test]
fn every_kernel_variant_matches_native_reference() {
    let engine = SpmmEngine::new(artifact_dir()).unwrap();
    let a = small_matrix(100, 90, 0.08, 1001);
    let h = engine.register(a.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(1002);
    for n in [1usize, 4] {
        let x = DenseMatrix::random(90, n, 1.0, &mut rng);
        let mut want = DenseMatrix::zeros(100, n);
        spmm_reference(&a, &x, &mut want);
        for kind in KernelKind::ALL {
            let resp = engine.spmm_with(h, &x, kind).unwrap();
            assert_eq!(resp.y.rows, 100);
            assert_eq!(resp.y.cols, n);
            let max_err = resp
                .y
                .data
                .iter()
                .zip(&want.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err < 1e-4,
                "{} n={n}: max err {max_err}",
                kind.label()
            );
        }
    }
}

#[test]
fn adaptive_path_selects_and_executes() {
    let engine = SpmmEngine::new(artifact_dir()).unwrap();
    // short-row matrix at n=1 → expect a PR kernel per the Fig. 4 rules
    let a = small_matrix(400, 400, 0.008, 1003);
    let h = engine.register(a.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(1004);
    let x = DenseMatrix::random(400, 1, 1.0, &mut rng);
    let resp = engine.spmm(h, &x).unwrap();
    assert!(
        resp.kernel.is_parallel_reduction(),
        "expected PR at n=1, got {}",
        resp.kernel.label()
    );
    // wide request → SR family
    let x32 = DenseMatrix::random(400, 32, 1.0, &mut rng);
    let resp32 = engine.spmm(h, &x32).unwrap();
    assert!(!resp32.kernel.is_parallel_reduction());
    assert_eq!(engine.metrics.requests(), 2);
}

#[test]
fn routes_to_bigger_bucket_and_odd_n_pads() {
    let engine = SpmmEngine::new(artifact_dir()).unwrap();
    // 600 rows exceed the s bucket (512) → must route to m
    let a = small_matrix(600, 600, 0.005, 1005);
    let h = engine.register(a.clone()).unwrap();
    let mut rng = Xoshiro256::seeded(1006);
    // n=3 routes to the n=4 artifact and slices back
    let x = DenseMatrix::random(600, 3, 1.0, &mut rng);
    let resp = engine.spmm(h, &x).unwrap();
    assert!(resp.artifact.contains("_m_n4"), "artifact {}", resp.artifact);
    let mut want = DenseMatrix::zeros(600, 3);
    spmm_reference(&a, &x, &mut want);
    let max_err = resp
        .y
        .data
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-4, "max err {max_err}");
}

#[test]
fn oversize_matrix_is_rejected_cleanly() {
    let engine = SpmmEngine::new(artifact_dir()).unwrap();
    let a = small_matrix(5000, 5000, 0.002, 1007);
    let h = engine.register(a).unwrap();
    let mut rng = Xoshiro256::seeded(1008);
    let x = DenseMatrix::random(5000, 4, 1.0, &mut rng);
    let err = engine.spmm(h, &x).unwrap_err().to_string();
    assert!(err.contains("bucket"), "unexpected error: {err}");
}

#[test]
fn dimension_mismatch_is_rejected() {
    let engine = SpmmEngine::new(artifact_dir()).unwrap();
    let a = small_matrix(50, 60, 0.1, 1009);
    let h = engine.register(a).unwrap();
    let x = DenseMatrix::zeros(50, 4); // should be 60 rows
    assert!(engine.spmm(h, &x).is_err());
    assert_eq!(engine.metrics.errors(), 1);
}

#[test]
fn packed_operand_cache_reuses_across_requests() {
    let engine = SpmmEngine::new(artifact_dir()).unwrap();
    let a = small_matrix(200, 200, 0.02, 1010);
    let h = engine.register(a).unwrap();
    let mut rng = Xoshiro256::seeded(1011);
    let x = DenseMatrix::random(200, 4, 1.0, &mut rng);
    let r1 = engine.spmm(h, &x).unwrap();
    let r2 = engine.spmm(h, &x).unwrap();
    assert_eq!(r1.y, r2.y);
    // second request should not be slower by more than ~compile+pack time
    assert_eq!(engine.metrics.requests(), 2);
}
