//! Integration: the end-to-end GCN training path (L1 Pallas kernel inside
//! L2 JAX train step executed by the L3 Rust runtime).

use ge_spmm::gnn::{GcnTrainer, GraphConfig, SyntheticGraph};
use ge_spmm::runtime::Engine;
use std::path::Path;

fn artifact_dir() -> &'static Path {
    let p = Path::new("artifacts");
    assert!(
        p.join("manifest.json").exists(),
        "artifacts/manifest.json missing — run `make artifacts` first"
    );
    p
}

#[test]
fn gcn_step_runs_and_loss_decreases() {
    let engine = Engine::new(artifact_dir()).unwrap();
    let graph = SyntheticGraph::generate(GraphConfig::default(), 31);
    let mut trainer = GcnTrainer::new(&engine, &graph, 32).unwrap();
    let report = trainer.train(20, 0).unwrap();
    assert_eq!(report.losses.len(), 20);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    assert!(
        report.losses[19] < report.losses[0],
        "loss did not decrease: {} -> {}",
        report.losses[0],
        report.losses[19]
    );
}

#[test]
fn gcn_forward_produces_finite_logits() {
    let engine = Engine::new(artifact_dir()).unwrap();
    let graph = SyntheticGraph::generate(GraphConfig::default(), 33);
    let trainer = GcnTrainer::new(&engine, &graph, 34).unwrap();
    let logits = trainer.forward().unwrap();
    assert_eq!(
        logits.len(),
        graph.config.nodes_padded * graph.config.classes
    );
    assert!(logits.iter().all(|v| v.is_finite()));
    let acc = trainer.train_accuracy().unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn mismatched_graph_is_rejected() {
    let engine = Engine::new(artifact_dir()).unwrap();
    let cfg = GraphConfig {
        nodes: 100,
        nodes_padded: 128,
        feats: 8, // artifact expects 64
        classes: 3,
        width: 8,
        communities: 3,
        avg_degree: 3.0,
        label_frac: 0.3,
    };
    let graph = SyntheticGraph::generate(cfg, 35);
    assert!(GcnTrainer::new(&engine, &graph, 36).is_err());
}
