//! SDDMM agreement and robustness surface (ISSUE 5):
//!
//! - all four SDDMM designs, directly and through `NativeBackend` /
//!   `ShardedBackend` / the engine, are **bit-for-bit** equal to the
//!   dense reference across generator families (the kernels share one
//!   canonical dot-product summation order — see `sddmm` module docs);
//! - degenerate inputs (`nnz == 0`, `rows == 0`, `d == 0`) are no-ops;
//! - non-finite entries in dense rows no non-zero references can never
//!   leak into outputs, while genuinely referenced NaNs propagate;
//! - the op-tagged server path round-trips SDDMM requests next to SpMM
//!   traffic;
//! - the fused SDDMM→softmax→SpMM attention forward runs through the
//!   serving engine (sharded + cached) with per-op kernel-selection
//!   counters visible in `Metrics` — the acceptance bar of ISSUE 5.

use ge_spmm::backend::{NativeBackend, SpmmBackend};
use ge_spmm::coordinator::server::{Request, Server, ServerConfig, ServerReply};
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::gen::powerlaw::PowerLawConfig;
use ge_spmm::gen::rmat::RmatConfig;
use ge_spmm::gnn::AttentionLayer;
use ge_spmm::kernels::dense::sddmm_reference;
use ge_spmm::kernels::{KernelKind, SparseOp, WARP};
use ge_spmm::sddmm;
use ge_spmm::shard::ShardedBackend;
use ge_spmm::sparse::{CooMatrix, CsrMatrix, DenseMatrix, SegmentedMatrix};
use ge_spmm::util::prng::Xoshiro256;
use ge_spmm::util::proptest::{assert_close, run_prop};
use ge_spmm::util::threadpool::ThreadPool;
use std::sync::{mpsc, Arc};
use std::time::Duration;

#[test]
fn all_designs_bit_identical_across_generator_families() {
    run_prop("sddmm 2x2 space vs reference", 24, |g| {
        let family = *g.choose(&[0usize, 1, 2, 3]);
        let coo = match family {
            0 => {
                let rows = g.dim() * 3 + 2;
                let cols = g.dim() * 3 + 2;
                CooMatrix::random_uniform(rows, cols, 0.2, g.rng())
            }
            1 => {
                let rows = g.dim() * 4 + 8;
                PowerLawConfig {
                    rows,
                    cols: rows,
                    alpha: 1.7,
                    min_row: 1,
                    max_row: (rows / 2).max(2),
                }
                .generate(g.rng())
            }
            2 => ge_spmm::gen::banded::banded(g.dim() * 4 + 4, &[-1, 0, 1], g.rng()),
            _ => RmatConfig::new(6, 4.0).generate(g.rng()),
        };
        let a = CsrMatrix::from_coo(&coo);
        let seg = SegmentedMatrix::from_csr(&a, WARP);
        let d = *g.choose(&[1usize, 7, 32, 64]);
        let u = DenseMatrix::from_vec(a.rows, d, g.vec_f32(a.rows * d));
        let v = DenseMatrix::from_vec(a.cols, d, g.vec_f32(a.cols * d));
        let mut want = vec![0f32; a.nnz()];
        sddmm_reference(&a, &u, &v, &mut want);
        // the four designs, run directly
        let workers = *g.choose(&[1usize, 3, 6]);
        for kind in KernelKind::ALL {
            let mut got = vec![0f32; a.nnz()];
            sddmm::run(kind, &a, &seg, &u, &v, &mut got, &ThreadPool::new(workers));
            if got != want {
                return Err(format!("{kind:?} family={family} d={d}"));
            }
        }
        // ... and through the backends (fixed-kernel sharded included)
        let native = NativeBackend::new(ThreadPool::new(workers));
        let op = native.prepare(&a).map_err(|e| e.to_string())?;
        let sharded = ShardedBackend::new(*g.choose(&[2usize, 4]));
        let sop = sharded.prepare(&a).map_err(|e| e.to_string())?;
        for kind in KernelKind::ALL {
            let e1 = native
                .execute_sddmm(&op, &u, &v, kind)
                .map_err(|e| e.to_string())?;
            let e2 = sharded
                .execute_sddmm(&sop, &u, &v, kind)
                .map_err(|e| e.to_string())?;
            if e1.values != want || e2.values != want {
                return Err(format!("backend {kind:?} family={family} d={d}"));
            }
        }
        Ok(())
    });
}

#[test]
fn degenerate_shapes_are_noops() {
    let backend = NativeBackend::default();
    // nnz == 0 (rows > 0), rows == 0, and d == 0
    for (rows, cols) in [(5usize, 7usize), (0, 7), (0, 0)] {
        let a = CsrMatrix::from_coo(&CooMatrix::new(rows, cols));
        let op = backend.prepare(&a).unwrap();
        for d in [0usize, 3] {
            let u = DenseMatrix::zeros(rows, d);
            let v = DenseMatrix::zeros(cols, d);
            for kind in KernelKind::ALL {
                let exec = backend.execute_sddmm(&op, &u, &v, kind).unwrap();
                assert!(exec.values.is_empty(), "{rows}x{cols} d={d} {kind:?}");
            }
        }
    }
    // d == 0 on a non-empty matrix: every sampled dot is the empty sum
    let mut coo = CooMatrix::new(3, 4);
    coo.push(0, 1, 2.0);
    coo.push(2, 3, -1.0);
    let a = CsrMatrix::from_coo(&coo);
    let op = backend.prepare(&a).unwrap();
    for kind in KernelKind::ALL {
        let exec = backend
            .execute_sddmm(&op, &DenseMatrix::zeros(3, 0), &DenseMatrix::zeros(4, 0), kind)
            .unwrap();
        assert_eq!(exec.values, vec![0.0; 2], "{kind:?}");
    }
}

/// Fixture mirroring `tests/robustness.rs`: a skewed pattern where
/// column 0 of the dense operands is never referenced and carries
/// non-finite values.
fn nan_fixture() -> (CsrMatrix, DenseMatrix, DenseMatrix) {
    let mut coo = CooMatrix::new(40, 50);
    for c in 1..45 {
        coo.push(7, c, 0.25 * c as f32);
    }
    for r in 0..40 {
        if r != 7 {
            coo.push(r, 1 + (r * 3) % 49, 1.0 + r as f32);
        }
    }
    let a = CsrMatrix::from_coo(&coo);
    let d = 3;
    let mut rng = Xoshiro256::seeded(61);
    let u = DenseMatrix::random(40, d, 1.0, &mut rng);
    let mut v = DenseMatrix::random(50, d, 1.0, &mut rng);
    // poison V's row 0: no non-zero sits in column 0
    v.data[0] = f32::NAN;
    v.data[1] = f32::INFINITY;
    v.data[2] = f32::NEG_INFINITY;
    (a, u, v)
}

#[test]
fn unreferenced_poison_cannot_leak_and_real_nan_propagates() {
    let (a, u, v) = nan_fixture();
    let seg = SegmentedMatrix::from_csr(&a, WARP);
    let mut want = vec![0f32; a.nnz()];
    sddmm_reference(&a, &u, &v, &mut want);
    assert!(want.iter().all(|x| x.is_finite()), "fixture broken");
    for kind in KernelKind::ALL {
        for workers in [1usize, 4] {
            let mut got = vec![0f32; a.nnz()];
            sddmm::run(kind, &a, &seg, &u, &v, &mut got, &ThreadPool::new(workers));
            assert_eq!(got, want, "{kind:?} workers={workers}");
        }
    }
    // now reference the poisoned column: its sampled values must go NaN,
    // everything else must stay bit-identical
    let mut coo = CooMatrix::new(40, 50);
    for r in 0..40 {
        if r != 7 {
            coo.push(r, 1 + (r * 3) % 49, 1.0 + r as f32);
        }
    }
    coo.push(7, 0, 1.0); // touches poisoned column 0
    let a2 = CsrMatrix::from_coo(&coo);
    let seg2 = SegmentedMatrix::from_csr(&a2, WARP);
    let mut want2 = vec![0f32; a2.nnz()];
    sddmm_reference(&a2, &u, &v, &mut want2);
    assert!(want2.iter().any(|x| x.is_nan()), "fixture refs poison");
    for kind in KernelKind::ALL {
        let mut got = vec![0f32; a2.nnz()];
        sddmm::run(kind, &a2, &seg2, &u, &v, &mut got, &ThreadPool::new(3));
        for (i, (g, w)) in got.iter().zip(&want2).enumerate() {
            if w.is_nan() {
                assert!(g.is_nan(), "{kind:?} [{i}] dropped a real NaN");
            } else {
                assert_eq!(g.to_bits(), w.to_bits(), "{kind:?} [{i}]");
            }
        }
    }
}

#[test]
fn server_round_trips_op_tagged_requests() {
    let mut rng = Xoshiro256::seeded(71);
    let a = CsrMatrix::from_coo(&CooMatrix::random_uniform(60, 60, 0.1, &mut rng));
    let engine = Arc::new(SpmmEngine::native().with_prepared_cache(16 << 20));
    let h = engine.register(a.clone()).unwrap();
    let server = Server::start(
        engine.clone(),
        ServerConfig {
            max_width: 4,
            max_delay: Duration::from_millis(2),
            workers: 2,
            max_queue: 64,
        },
    );
    let d = 6;
    let u = DenseMatrix::random(60, d, 1.0, &mut rng);
    let v = DenseMatrix::random(60, d, 1.0, &mut rng);
    let mut want_vals = vec![0f32; a.nnz()];
    sddmm_reference(&a, &u, &v, &mut want_vals);
    let x = DenseMatrix::random(60, 4, 1.0, &mut rng);
    let mut want_y = DenseMatrix::zeros(60, 4);
    ge_spmm::kernels::dense::spmm_reference(&a, &x, &mut want_y);

    let (stx, srx) = mpsc::channel();
    assert!(server.submit(Request::sddmm(h, u, v, 1, stx)));
    let (mtx, mrx) = mpsc::channel();
    assert!(server.submit(Request::spmm(h, x, 2, mtx)));

    match srx.recv_timeout(Duration::from_secs(60)).unwrap() {
        ServerReply::Ok(r) => {
            assert_eq!(r.tag, 1);
            assert_eq!(r.op, SparseOp::Sddmm);
            assert_eq!((r.y.rows, r.y.cols), (a.nnz(), 1));
            assert_eq!(r.y.data, want_vals, "sampled values round-trip");
        }
        ServerReply::Err(e) => panic!("sddmm request failed: {e}"),
    }
    match mrx.recv_timeout(Duration::from_secs(60)).unwrap() {
        ServerReply::Ok(r) => {
            assert_eq!(r.tag, 2);
            assert_eq!(r.op, SparseOp::Spmm);
            assert_close(&r.y.data, &want_y.data, 1e-4, 1e-4).unwrap();
        }
        ServerReply::Err(e) => panic!("spmm request failed: {e}"),
    }
    // bad sddmm operands are rejected without touching other requests
    let (btx, brx) = mpsc::channel();
    assert!(server.submit(Request::sddmm(
        h,
        DenseMatrix::zeros(60, 3),
        DenseMatrix::zeros(60, 4),
        3,
        btx
    )));
    match brx.recv_timeout(Duration::from_secs(60)).unwrap() {
        ServerReply::Err(e) => assert!(e.contains("sddmm operand"), "{e}"),
        ServerReply::Ok(_) => panic!("operand mismatch must not succeed"),
    }
    server.shutdown();
    // per-op accounting on the shared engine
    assert_eq!(engine.metrics.sddmm_requests(), 1);
    assert_eq!(engine.metrics.requests(), 1);
    assert_eq!(engine.metrics.errors(), 1);
    assert_eq!(engine.metrics.sddmm_kernel_counts().iter().sum::<u64>(), 1);
}

#[test]
fn fused_attention_runs_through_the_serving_engine() {
    // The ISSUE-5 acceptance bar: SDDMM→softmax→SpMM end to end on the
    // serving shape (prepared-matrix cache + size routing with the
    // threshold forced low, so both sparse stages take the sharded
    // per-shard-adaptive path), per-op counters visible.
    let mut rng = Xoshiro256::seeded(81);
    let n = 200;
    let adj = {
        let coo = CooMatrix::random_uniform(n, n, 0.04, &mut rng);
        let csr = CsrMatrix::from_coo(&coo);
        csr.with_values(vec![1.0; csr.nnz()])
    };
    let x = DenseMatrix::random(n, 12, 1.0, &mut rng);
    let layer = AttentionLayer::new(12, 8, 82);

    // ground truth from the plain native engine (itself pinned against a
    // dense attention reference in the attention unit tests)
    let native = SpmmEngine::native();
    let hn = native.register(adj.clone()).unwrap();
    let want = layer.forward(&native, &adj, hn, &x).unwrap();

    let serving = SpmmEngine::serving(64 << 20, 1, 2);
    let hs = serving.register(adj.clone()).unwrap();
    let got = layer.forward(&serving, &adj, hs, &x).unwrap();
    assert_close(&got.y.data, &want.y.data, 1e-4, 1e-4).unwrap();
    assert_eq!(
        got.attention.values, want.attention.values,
        "SDDMM + softmax are bit-identical across engine shapes"
    );

    // per-op kernel-selection counters, both grains
    assert_eq!(serving.metrics.sddmm_requests(), 1);
    assert_eq!(serving.metrics.requests(), 1);
    assert_eq!(serving.metrics.sddmm_kernel_counts().iter().sum::<u64>(), 1);
    assert_eq!(serving.metrics.kernel_counts().iter().sum::<u64>(), 1);
    assert!(
        serving.metrics.sddmm_shard_executions() >= 2,
        "score stage fanned out"
    );
    assert!(
        serving.metrics.shard_executions() >= 2,
        "aggregation stage fanned out"
    );
    // both registrations (adjacency + intermediate attention) went
    // through the prepared-matrix cache
    assert_eq!(serving.metrics.cache_misses(), 2);
}
