//! Differential mutation-replay harness — the correctness anchor for
//! dynamic-graph delta updates.
//!
//! A deterministic R-MAT churn stream (`gen::churn`) drives an engine
//! through hundreds of [`EdgeDelta`] batches via
//! `SpmmEngine::apply_delta`. After EVERY batch, a from-scratch engine
//! of the same shape registers the stream's ground-truth matrix, and
//! the patched engine's SpMM and SDDMM outputs must equal the fresh
//! engine's bit for bit on all four kernels. Identical matrices, the
//! same backend shape, and deterministic kernels mean the two engines
//! execute the same instruction sequence, so exact `f32` equality is
//! the correct bar even with real-valued weights.
//!
//! Coverage across the test functions: value-only churn (patched in
//! place) and mixed structural churn (re-prepared on the unsharded
//! backend, fingerprint-gated partial re-preparation on the sharded
//! one), blocked and merge-path traversal, sharded (k=2, k=3 and k=4)
//! and unsharded backends, the prepared cache rotating with the epoch,
//! concurrent server traffic in flight while the mutation stream
//! replays, and a heavy-growth phase that must trip the drift detector
//! and leave delta-grain reselection entries in the audit log. The
//! batch count across the suite is 275 — past the 200 the acceptance
//! bar asks for.

mod common;
use common::int_dense;

use ge_spmm::backend::{NativeBackend, TraversalMode};
use ge_spmm::coordinator::server::{Request, Server, ServerConfig, ServerReply};
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::gen::rmat::RmatConfig;
use ge_spmm::gen::{ChurnConfig, ChurnStream};
use ge_spmm::kernels::dense::spmm_reference;
use ge_spmm::kernels::KernelKind;
use ge_spmm::sparse::{CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Dense width for the SpMM comparisons.
const N: usize = 8;
/// Dot width for the SDDMM comparisons.
const D: usize = 8;

/// Replay `batches` churn batches onto `engine` via `apply_delta`,
/// comparing all four kernels' SpMM and SDDMM outputs bit-for-bit
/// against a from-scratch engine built by `fresh` after every batch.
/// Returns `(patched, reprepared)` counts over the effective batches.
fn replay(
    engine: &SpmmEngine,
    fresh: impl Fn() -> SpmmEngine,
    stream: &mut ChurnStream,
    batches: usize,
    seed: u64,
) -> (usize, usize) {
    let h = engine.register(stream.current().clone()).unwrap();
    let mut rng = Xoshiro256::seeded(seed);
    let (mut patched, mut reprepared) = (0, 0);
    for b in 0..batches {
        let delta = stream.next_batch();
        let out = engine.apply_delta(h, &delta).unwrap();
        if out.report.touched() > 0 {
            if out.patched {
                patched += 1;
            } else {
                reprepared += 1;
            }
        }
        assert_eq!(
            out.epoch,
            stream.current().epoch,
            "batch {b}: engine epoch tracks the stream"
        );

        let truth = fresh();
        let ht = truth.register(stream.current().clone()).unwrap();
        let dim = stream.current().rows;
        let x = int_dense(dim, N, &mut rng);
        let u = int_dense(dim, D, &mut rng);
        let v = int_dense(dim, D, &mut rng);
        for kind in KernelKind::ALL {
            let got = engine.spmm_with(h, &x, kind).unwrap();
            let want = truth.spmm_with(ht, &x, kind).unwrap();
            assert_eq!(got.y.data, want.y.data, "batch {b} spmm {}", kind.label());
            let got = engine.sddmm_with(h, &u, &v, kind).unwrap();
            let want = truth.sddmm_with(ht, &u, &v, kind).unwrap();
            assert_eq!(got.values, want.values, "batch {b} sddmm {}", kind.label());
        }
    }
    (patched, reprepared)
}

#[test]
fn value_only_churn_patches_in_place_on_the_cached_native_engine() {
    let engine = SpmmEngine::with_backend(Box::new(
        NativeBackend::default().with_traversal(TraversalMode::Blocked),
    ))
    .with_prepared_cache(64 << 20);
    let config = ChurnConfig::new(RmatConfig::new(6, 4.0)).value_only();
    let mut stream = ChurnStream::new(config, 101);
    let fresh = || {
        SpmmEngine::with_backend(Box::new(
            NativeBackend::default().with_traversal(TraversalMode::Blocked),
        ))
    };
    let (patched, reprepared) = replay(&engine, fresh, &mut stream, 60, 201);
    assert_eq!(patched, 60, "weight updates never rebuild prepared state");
    assert_eq!(reprepared, 0);
    // the epoch-rotating cache key replaced (never accumulated) entries
    assert_eq!(engine.cache_usage().unwrap().0, 1);
}

#[test]
fn mixed_churn_agrees_under_merge_path_traversal() {
    let make = || {
        SpmmEngine::with_backend(Box::new(
            NativeBackend::default().with_traversal(TraversalMode::MergePath),
        ))
    };
    let engine = make();
    let mut stream = ChurnStream::new(ChurnConfig::new(RmatConfig::new(6, 4.0)), 102);
    let (patched, reprepared) = replay(&engine, make, &mut stream, 60, 202);
    assert!(reprepared > 0, "structural churn forces re-preparation");
    assert_eq!(patched + reprepared, 60, "every mixed batch touches");
}

#[test]
fn value_only_churn_patches_shard_locally_on_the_sharded_engine() {
    let engine = SpmmEngine::sharded(2);
    let config = ChurnConfig::new(RmatConfig::new(6, 4.0)).value_only();
    let mut stream = ChurnStream::new(config, 103);
    let (patched, reprepared) = replay(&engine, || SpmmEngine::sharded(2), &mut stream, 60, 203);
    assert_eq!(patched, 60, "sharded backends forward value patches per shard");
    assert_eq!(reprepared, 0);
}

#[test]
fn structural_churn_patches_partially_on_the_sharded_engine() {
    let engine = SpmmEngine::sharded(4);
    // Gentle structural churn — a few edges per batch on a 128-row base —
    // so any given batch leaves most of the four shards untouched. The
    // fingerprint gate must reuse those shards' operands and rebuild only
    // the touched ones, while every output stays bit-for-bit equal to a
    // from-scratch engine (checked by `replay` after every batch).
    let config = ChurnConfig {
        base: RmatConfig::new(7, 4.0),
        inserts: 2,
        deletes: 1,
        updates: 2,
    };
    let mut stream = ChurnStream::new(config, 106);
    let (patched, reprepared) = replay(&engine, || SpmmEngine::sharded(4), &mut stream, 30, 206);
    assert_eq!(
        patched, 30,
        "structural deltas patch in place on the sharded backend (fingerprint-gated)"
    );
    assert_eq!(reprepared, 0);
    let reused = engine.metrics.shard_operands_reused();
    let redone = engine.metrics.shard_operands_reprepared();
    assert_eq!(
        reused + redone,
        30 * 4,
        "every structural batch accounts for all four shard operands"
    );
    assert!(redone >= 30, "each batch rebuilds at least the shard it touched");
    assert!(reused > 0, "untouched shards are reused, not rebuilt");
}

#[test]
fn sharded_replay_agrees_while_server_requests_are_in_flight() {
    let engine = Arc::new(SpmmEngine::sharded(3));
    // A stable co-tenant matrix for the server traffic. Its values are
    // quantized to integers so every f32 partial sum is exact and the
    // replies can be checked against the serial reference regardless of
    // which kernel the engine picks.
    let mut stable = CsrMatrix::from_coo(&RmatConfig::uniform(6, 4.0).generate(
        &mut Xoshiro256::seeded(7),
    ));
    for v in &mut stable.values {
        *v = (*v * 8.0).round();
    }
    let hs = engine.register(stable.clone()).unwrap();
    let server = Server::start(
        engine.clone(),
        ServerConfig {
            max_width: 8,
            max_delay: Duration::from_millis(1),
            workers: 2,
            max_queue: 4096,
        },
    );

    let stop = AtomicBool::new(false);
    let mut stream = ChurnStream::new(ChurnConfig::new(RmatConfig::new(6, 4.0)), 104);
    std::thread::scope(|s| {
        let producer = s.spawn(|| {
            let mut rng = Xoshiro256::seeded(77);
            let mut served = 0u64;
            while !stop.load(Ordering::Acquire) {
                let tag = served;
                let x = int_dense(stable.cols, 1 + (tag % 3) as usize, &mut rng);
                let mut want = DenseMatrix::zeros(stable.rows, x.cols);
                spmm_reference(&stable, &x, &mut want);
                let (rtx, rrx) = mpsc::channel();
                assert!(server.submit(Request::spmm(hs, x, tag, rtx)));
                match rrx.recv_timeout(Duration::from_secs(60)).unwrap() {
                    ServerReply::Ok(r) => {
                        assert_eq!(r.tag, tag);
                        assert_eq!(r.y.data, want.data, "stable co-tenant reply, tag {tag}");
                        served += 1;
                    }
                    ServerReply::Err(e) => panic!("stable request failed mid-replay: {e}"),
                }
            }
            served
        });

        let (patched, reprepared) =
            replay(&engine, || SpmmEngine::sharded(3), &mut stream, 60, 204);
        assert_eq!(patched + reprepared, 60);
        stop.store(true, Ordering::Release);
        let served = producer.join().unwrap();
        assert!(served > 0, "server answered traffic during the replay");
    });
    server.shutdown();
    assert_eq!(engine.metrics.errors(), 0);
}

#[test]
fn heavy_growth_trips_drift_reselection_into_the_audit_log() {
    let engine = SpmmEngine::native();
    // Insert-only churn: each batch lands ~160 skewed edges on a ~250-nnz
    // base, pushing nnz (and avg_row) far past the 25% drift threshold.
    let config = ChurnConfig {
        base: RmatConfig::new(6, 4.0),
        inserts: 160,
        deletes: 0,
        updates: 4,
    };
    let mut stream = ChurnStream::new(config, 105);
    let before = stream.current().nnz();
    let (patched, reprepared) = replay(&engine, SpmmEngine::native, &mut stream, 5, 205);
    assert_eq!(patched, 0, "insert batches are structural");
    assert_eq!(reprepared, 5);
    assert!(
        stream.current().nnz() as f64 > before as f64 * 1.25,
        "growth phase moved nnz past the drift threshold: {} -> {}",
        before,
        stream.current().nnz()
    );

    let entries = engine.metrics.audit().entries();
    let drift: Vec<_> = entries.iter().filter(|e| e.grain == "delta").collect();
    assert!(
        drift.len() >= 2,
        "drift re-selection recorded for both ops, got {}",
        drift.len()
    );
    assert!(drift.iter().any(|e| e.selector == "drift"));
    assert!(drift.iter().any(|e| e.selector == "drift-sddmm"));
    assert!(
        drift.iter().all(|e| e.matrix == Some(0)),
        "delta-grain entries name the mutated registration"
    );
}
