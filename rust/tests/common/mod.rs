//! Helpers shared by the integration-test binaries.

use ge_spmm::sparse::DenseMatrix;
use ge_spmm::util::prng::Xoshiro256;

/// Integer-valued dense operand (entries in -8..=8) — every f32 partial
/// sum over it is exactly representable, the discipline the bit-for-bit
/// agreement tests rely on (see `backend_agreement.rs`).
pub fn int_dense(rows: usize, cols: usize, rng: &mut Xoshiro256) -> DenseMatrix {
    let data = (0..rows * cols)
        .map(|_| (rng.below(17) as i64 - 8) as f32)
        .collect();
    DenseMatrix::from_vec(rows, cols, data)
}
