//! Observability integration: the request-lifecycle trace a served
//! request leaves behind (admission → batch → dispatch → shard fan-out →
//! kernel), the selector decision audit whose recorded features and
//! thresholds must *reproduce* the chosen kernel, the lock-free latency
//! histograms' exactness under concurrency and their quantile accuracy
//! against an exact sort, flight-recorder wraparound under engine
//! traffic, and the exposition surface (JSON snapshot + Prometheus text)
//! over a live serving engine. Second-layer observability rides the same
//! fixtures: roofline workload accounting must match the analytic
//! flop/byte model exactly (unsharded and per-shard), selector regret
//! must fold to zero under an always-optimal selector, the Chrome
//! trace-event export must be valid well-nested JSON, and the SLO
//! burn-rate state must flip on an induced latency breach on the served
//! path.

use ge_spmm::coordinator::metrics::Metrics;
use ge_spmm::coordinator::server::{Request, Server, ServerConfig, ServerReply};
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::kernels::{KernelKind, SparseOp};
use ge_spmm::obs::expo;
use ge_spmm::obs::hist::AtomicHistogram;
use ge_spmm::obs::Grain;
use ge_spmm::selector::{AdaptiveSelector, SddmmSelector};
use ge_spmm::sparse::{CooMatrix, CsrMatrix};
use ge_spmm::util::json::Json;
use ge_spmm::util::prng::Xoshiro256;
use std::sync::{mpsc, Arc};
use std::time::Duration;

mod common;
use common::int_dense;

fn uniform_csr(rows: usize, cols: usize, density: f64, seed: u64) -> CsrMatrix {
    let mut rng = Xoshiro256::seeded(seed);
    CsrMatrix::from_coo(&CooMatrix::random_uniform(rows, cols, density, &mut rng))
}

/// A serving engine sized so `small` stays on the unsharded route and
/// `large` fans out over 2 shards, plus both registered handles.
fn serving_pair() -> (
    Arc<SpmmEngine>,
    ge_spmm::coordinator::engine::MatrixHandle,
    ge_spmm::coordinator::engine::MatrixHandle,
) {
    let small = uniform_csr(64, 48, 0.05, 71);
    let large = uniform_csr(512, 48, 0.08, 72);
    assert!(large.nnz() > small.nnz());
    let engine = Arc::new(SpmmEngine::serving(64 << 20, small.nnz() + 1, 2));
    let hs = engine.register(small).unwrap();
    let hl = engine.register(large).unwrap();
    (engine, hs, hl)
}

/// Rebuild the SpMM selector from an audit entry's recorded thresholds
/// and replay it on the recorded features: the decision must reproduce.
fn replay_adaptive(e: &ge_spmm::obs::AuditEntry) {
    let sel = AdaptiveSelector {
        n_threshold: e.threshold("t_n").unwrap() as usize,
        t_avg: e.threshold("t_avg").unwrap(),
        t_cv: e.threshold("t_cv").unwrap(),
        t_mp: e.threshold("t_mp").unwrap(),
    };
    assert_eq!(
        sel.select(&e.features, e.n),
        e.kernel,
        "audit entry must reproduce its decision: {}",
        e.line()
    );
    assert!(e.rule.contains(e.kernel.label()), "{}", e.rule);
}

#[test]
fn histograms_record_concurrently_without_loss() {
    let m = Arc::new(Metrics::default());
    std::thread::scope(|s| {
        for _ in 0..8 {
            let m = m.clone();
            s.spawn(move || {
                for _ in 0..500 {
                    m.record(KernelKind::SrRs, Duration::from_micros(1));
                }
                for _ in 0..250 {
                    m.record_shard(KernelKind::PrWb, Duration::from_micros(2));
                }
                for _ in 0..125 {
                    m.record_sddmm(KernelKind::SrWb, Duration::from_micros(3));
                    m.record_sddmm_shard(KernelKind::PrRs, Duration::from_micros(4));
                }
            });
        }
    });
    // exact totals: nothing dropped, nothing double-counted, no bank
    // bleeding into another op × grain × kernel cell
    let cases = [
        (SparseOp::Spmm, Grain::Request, KernelKind::SrRs, 4000u64, 1_000u64),
        (SparseOp::Spmm, Grain::Shard, KernelKind::PrWb, 2000, 2_000),
        (SparseOp::Sddmm, Grain::Request, KernelKind::SrWb, 1000, 3_000),
        (SparseOp::Sddmm, Grain::Shard, KernelKind::PrRs, 1000, 4_000),
    ];
    for (op, grain, kernel, count, each_ns) in cases {
        let snap = m.latency_histogram(op, grain, kernel);
        assert_eq!(snap.count, count, "{op:?}/{grain:?}/{kernel:?}");
        assert_eq!(snap.sum, count * each_ns);
        assert_eq!(snap.counts.iter().sum::<u64>(), count);
        assert_eq!(snap.max, each_ns);
        for other in KernelKind::ALL {
            if other != kernel {
                assert!(m.latency_histogram(op, grain, other).is_empty());
            }
        }
    }
    assert_eq!(m.requests(), 4000);
    assert_eq!(m.shard_executions(), 2000);
    assert_eq!(m.sddmm_requests(), 1000);
    assert_eq!(m.sddmm_shard_executions(), 1000);
}

#[test]
fn histogram_quantiles_match_an_exact_sort_within_bucket_bounds() {
    let h = AtomicHistogram::new();
    let mut rng = Xoshiro256::seeded(99);
    let mut samples: Vec<u64> = Vec::with_capacity(10_000);
    for _ in 0..10_000 {
        let v = rng.below(1_000_000) + 1;
        h.record(v);
        samples.push(v);
    }
    samples.sort_unstable();
    let snap = h.snapshot();
    assert_eq!(snap.count, 10_000);
    assert_eq!(snap.sum, samples.iter().sum::<u64>());
    assert_eq!(snap.max, *samples.last().unwrap());
    // the log-bucketed estimate answers the selected bucket's geometric
    // midpoint, so it sits within the √2 bucket width of the exact
    // nearest-rank value at every quantile
    for q in [0.0, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
        let rank = (q * (snap.count - 1) as f64).round() as usize;
        let exact = samples[rank] as f64;
        let est = snap.quantile(q);
        let ratio = est / exact;
        assert!(
            (1.0 / std::f64::consts::SQRT_2..=std::f64::consts::SQRT_2).contains(&ratio),
            "q={q}: estimate {est} vs exact {exact} (ratio {ratio})"
        );
    }
}

#[test]
fn flight_recorder_wraps_at_capacity_under_engine_traffic() {
    let engine = SpmmEngine::native();
    let h = engine.register(uniform_csr(48, 40, 0.1, 31)).unwrap();
    let mut rng = Xoshiro256::seeded(32);
    let x = int_dense(40, 4, &mut rng);
    let capacity = engine.metrics.recorder().capacity();
    let total = capacity as u64 + 6;
    for _ in 0..total {
        engine.spmm(h, &x).unwrap();
    }
    let recorder = engine.metrics.recorder();
    assert_eq!(recorder.committed(), total, "every direct call commits a trace");
    assert_eq!(recorder.len(), capacity, "ring keeps only the newest");
    let traces = recorder.traces();
    assert_eq!(traces.len(), capacity);
    for t in &traces {
        assert_eq!(t.label, "spmm#0");
        let dispatch = t.span("dispatch").expect("dispatch span");
        assert!(dispatch.duration_ns() > 0);
        assert!(dispatch.attr("artifact").unwrap().starts_with("native/"));
        let kernel = t.span("kernel").expect("kernel span");
        assert_eq!(kernel.parent, dispatch.id);
        assert!(kernel.duration_ns() > 0);
    }
    let dump = recorder.dump_json();
    assert_eq!(
        dump.get("committed").and_then(|j| j.as_usize()),
        Some(total as usize)
    );
    assert_eq!(
        dump.get("traces").and_then(|j| j.as_arr()).unwrap().len(),
        capacity
    );
}

#[test]
fn served_spmm_requests_leave_full_traces_and_reproducible_audits() {
    let (engine, hs, hl) = serving_pair();
    let server = Server::start(
        engine.clone(),
        ServerConfig {
            max_width: 1000,
            max_delay: Duration::from_millis(1),
            workers: 2,
            max_queue: 64,
        },
    );
    let mut rng = Xoshiro256::seeded(73);
    let mut replies = Vec::new();
    for (tag, h) in [(1u64, hs), (2u64, hl)] {
        let (rtx, rrx) = mpsc::channel();
        assert!(server.submit(Request::spmm(h, int_dense(48, 3, &mut rng), tag, rtx)));
        replies.push(rrx);
    }
    for rrx in replies {
        match rrx.recv_timeout(Duration::from_secs(60)).unwrap() {
            ServerReply::Ok(_) => {}
            ServerReply::Err(e) => panic!("served request failed: {e}"),
        }
    }
    server.shutdown();

    let traces = engine.metrics.recorder().traces();
    let find = |label: &str| {
        traces
            .iter()
            .find(|t| t.label == label)
            .unwrap_or_else(|| panic!("no trace labeled {label}"))
    };
    for (label, tag) in [("spmm#1", "1"), ("spmm#2", "2")] {
        let t = find(label);
        // admission: queue wait from submit (trace epoch) to dequeue
        let admission = t.span("admission").expect("admission span");
        assert_eq!(admission.attr("tag"), Some(tag));
        assert_eq!(admission.start_ns, 0);
        assert!(admission.end_ns > 0);
        // batch: the sole member of its deadline flush is the primary
        let batch = t.span("batch").expect("batch span");
        assert_eq!(batch.attr("batch_size"), Some("1"));
        // dispatch nests under the batch and carries the decision
        let dispatch = t.span("dispatch").expect("dispatch span");
        assert_eq!(dispatch.parent, batch.id);
        assert_eq!(dispatch.attr("op"), Some("spmm"));
        assert!(dispatch.attr("kernel").is_some());
        assert!(dispatch.attr("artifact").is_some());
        assert!(dispatch.duration_ns() > 0, "dispatch wraps real execution");
        // at least one kernel span with real duration under the dispatch
        let kernels = t.spans_named("kernel");
        assert!(!kernels.is_empty());
        assert!(kernels.iter().any(|k| k.duration_ns() > 0));
        for sp in &t.spans {
            assert!(sp.end_ns >= sp.start_ns, "{}: span {} runs backwards", label, sp.name);
        }
    }
    // the large request fans out: fanout → per-shard spans → native kernels
    let t2 = find("spmm#2");
    let fanout = t2.span("fanout").expect("fanout span");
    assert_eq!(fanout.attr("shards"), Some("2"));
    let shards = t2.spans_named("shard");
    assert_eq!(shards.len(), 2);
    for sp in &shards {
        assert_eq!(sp.parent, fanout.id, "shard spans parent to the fan-out");
        assert!(sp.attr("kernel").is_some());
    }
    let shard_ids: Vec<u64> = shards.iter().map(|sp| sp.id).collect();
    let native_kernels: Vec<_> = t2
        .spans_named("kernel")
        .into_iter()
        .filter(|k| k.attr("backend") == Some("native"))
        .collect();
    assert_eq!(native_kernels.len(), 2, "one inner kernel call per shard");
    for k in &native_kernels {
        assert!(shard_ids.contains(&k.parent), "kernel nests in its shard span");
        assert!(k.duration_ns() > 0);
    }
    let t1 = find("spmm#1");
    assert!(t1.span("fanout").is_none(), "small request stays unsharded");

    // every adaptive decision left an audit entry that reproduces it
    let audit = engine.metrics.audit();
    let entries = audit.entries();
    let requests: Vec<_> = entries.iter().filter(|e| e.grain == "request").collect();
    assert_eq!(requests.len(), 2);
    for &e in &requests {
        assert_eq!(e.op, SparseOp::Spmm);
        assert_eq!(e.selector, "adaptive");
        assert_eq!(e.n, 3);
        assert!(e.matrix.is_some());
        replay_adaptive(e);
    }
    assert_ne!(
        requests[0].matrix, requests[1].matrix,
        "one request-grain entry per registered matrix"
    );
    let shard_entries: Vec<_> = entries.iter().filter(|e| e.grain == "shard").collect();
    assert_eq!(shard_entries.len(), 2, "one shard-grain entry per fan-out shard");
    for &e in &shard_entries {
        assert_eq!(e.selector, "adaptive");
        assert!(e.shard.is_some());
        assert!(e.matrix.is_none());
        replay_adaptive(e);
    }
    assert_eq!(audit.recorded(), 4);
    let report = engine.explain(hs);
    assert!(report.contains("via adaptive"), "{report}");
    assert!(report.contains("thresholds"), "{report}");

    // serve-mode stats smoke: the same engine renders a full exposition
    let text = expo::prometheus_text(&engine.metrics);
    assert!(text.contains("ge_spmm_requests_total 2"), "{text}");
    assert!(text.contains("ge_spmm_shard_executions_total 2"), "{text}");
    assert!(text.contains("ge_spmm_audit_decisions_total 4"), "{text}");
    let req_kernel = requests[0].kernel.label();
    assert!(
        text.contains(&format!(
            "op=\"spmm\",grain=\"request\",kernel=\"{req_kernel}\",quantile=\"0.99\""
        )),
        "{text}"
    );
}

#[test]
fn served_sddmm_requests_trace_and_audit_the_second_op() {
    let (engine, hs, _hl) = serving_pair();
    let server = Server::start(
        engine.clone(),
        ServerConfig {
            max_width: 1000,
            max_delay: Duration::from_millis(1),
            workers: 1,
            max_queue: 16,
        },
    );
    let mut rng = Xoshiro256::seeded(74);
    let u = int_dense(64, 8, &mut rng);
    let v = int_dense(48, 8, &mut rng);
    let (rtx, rrx) = mpsc::channel();
    assert!(server.submit(Request::sddmm(hs, u, v, 9, rtx)));
    match rrx.recv_timeout(Duration::from_secs(60)).unwrap() {
        ServerReply::Ok(_) => {}
        ServerReply::Err(e) => panic!("served sddmm failed: {e}"),
    }
    server.shutdown();

    let traces = engine.metrics.recorder().traces();
    let t = traces
        .iter()
        .find(|t| t.label == "sddmm#9")
        .expect("sddmm trace");
    let admission = t.span("admission").expect("admission span");
    assert_eq!(admission.attr("tag"), Some("9"));
    let dispatch = t.span("dispatch").expect("dispatch span");
    assert_eq!(dispatch.attr("op"), Some("sddmm"));
    assert_eq!(dispatch.attr("d"), Some("8"));
    assert!(dispatch.duration_ns() > 0);
    let kernel = t.span("kernel").expect("kernel span");
    assert_eq!(kernel.attr("op"), Some("sddmm"));
    assert!(kernel.duration_ns() > 0);
    assert!(t.span("batch").is_none(), "sddmm executes unbatched");

    let entries = engine.metrics.audit().entries();
    let e = entries
        .iter()
        .find(|e| e.op == SparseOp::Sddmm)
        .expect("sddmm audit entry");
    assert_eq!(e.grain, "request");
    assert_eq!(e.selector, "sddmm");
    assert_eq!(e.n, 8);
    let sel = SddmmSelector {
        d_threshold: e.threshold("t_d").unwrap() as usize,
        t_cv: e.threshold("t_cv").unwrap(),
    };
    assert_eq!(
        sel.select(&e.features, e.n),
        e.kernel,
        "sddmm audit entry must reproduce its decision: {}",
        e.line()
    );
}

#[test]
fn stats_snapshot_matches_live_counters_and_roundtrips() {
    let (engine, hs, hl) = serving_pair();
    let mut rng = Xoshiro256::seeded(75);
    let x = int_dense(48, 6, &mut rng);
    let spmm_kernel = engine.spmm(hs, &x).unwrap().kernel;
    engine.spmm(hl, &x).unwrap();
    let u = int_dense(64, 8, &mut rng);
    let v = int_dense(48, 8, &mut rng);
    let sddmm_kernel = engine.sddmm(hs, &u, &v).unwrap().kernel;

    let snap = expo::snapshot(&engine.metrics);
    let counters = snap.get("counters").unwrap();
    let count_of = |key: &str| counters.get(key).unwrap().as_usize().unwrap() as u64;
    assert_eq!(count_of("requests"), engine.metrics.requests());
    assert_eq!(count_of("requests"), 2);
    assert_eq!(count_of("sddmm_requests"), 1);
    assert_eq!(count_of("shard_executions"), engine.metrics.shard_executions());
    assert_eq!(count_of("shard_executions"), 2);
    assert_eq!(count_of("errors"), 0);
    assert_eq!(count_of("cache_misses"), 2);

    // the per-op per-kernel latency rows carry live quantiles
    let kernels = snap.get("kernels").unwrap().as_arr().unwrap();
    assert_eq!(kernels.len(), 16, "2 ops x 2 grains x 4 kernels");
    let row_of = |op: &str, grain: &str, kernel: KernelKind| {
        kernels
            .iter()
            .find(|r| {
                r.get("op").unwrap().as_str() == Some(op)
                    && r.get("grain").unwrap().as_str() == Some(grain)
                    && r.get("kernel").unwrap().as_str() == Some(kernel.label())
            })
            .unwrap()
    };
    for (op, kernel) in [("spmm", spmm_kernel), ("sddmm", sddmm_kernel)] {
        let row = row_of(op, "request", kernel);
        assert!(row.get("count").unwrap().as_usize().unwrap() >= 1);
        assert!(row.get("p50_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(row.get("p99_ns").unwrap().as_f64().unwrap() > 0.0);
    }

    // Prometheus text includes per-op per-kernel p50/p99 series
    let text = expo::prometheus_text(&engine.metrics);
    for q in ["0.5", "0.99"] {
        assert!(
            text.contains(&format!(
                "ge_spmm_latency_ns{{op=\"spmm\",grain=\"request\",kernel=\"{}\",quantile=\"{q}\"}}",
                spmm_kernel.label()
            )),
            "{text}"
        );
        assert!(
            text.contains(&format!(
                "ge_spmm_latency_ns{{op=\"sddmm\",grain=\"request\",kernel=\"{}\",quantile=\"{q}\"}}",
                sddmm_kernel.label()
            )),
            "{text}"
        );
    }
    assert!(text.contains("ge_spmm_traces_committed_total 3"), "{text}");

    // the JSON snapshot is parseable interchange: reparse and re-render
    let reparsed = Json::parse(&snap.to_string_pretty()).unwrap();
    assert_eq!(reparsed, snap);
    assert_eq!(expo::prometheus_of(&reparsed).unwrap(), text);
}

#[test]
fn workload_accounting_matches_the_analytic_model_end_to_end() {
    use ge_spmm::kernels::registry;
    use ge_spmm::obs::workload;

    // unsharded: every direct request books exactly one workload record
    // under the canonical variant of the dispatched kernel
    let a = uniform_csr(64, 48, 0.05, 81);
    let (rows, nnz) = (a.rows, a.nnz());
    let engine = SpmmEngine::native();
    let h = engine.register(a).unwrap();
    let mut rng = Xoshiro256::seeded(82);
    let x = int_dense(48, 6, &mut rng);
    let resp = engine.spmm(h, &x).unwrap();
    let entry = registry().canonical(SparseOp::Spmm, resp.kernel);
    let est = workload::estimate(&entry.variant, rows, nnz, 6);
    assert_eq!(est.flops, 2 * nnz as u64 * 6, "SpMM flop model is 2·nnz·n");
    let t = engine.metrics.workload_totals(entry.id).expect("workload recorded");
    assert_eq!(t.execs, 1);
    assert_eq!(t.flops, est.flops);
    assert_eq!(t.bytes_read, est.bytes_read);
    assert_eq!(t.bytes_written, est.bytes_written);
    assert_eq!((t.rows, t.nnz), (rows as u64, nnz as u64));
    assert!(t.ns > 0 && t.achieved_gflops() > 0.0);
    assert_eq!(engine.metrics.workload_flops_total(), est.flops);

    let u = int_dense(64, 8, &mut rng);
    let v = int_dense(48, 8, &mut rng);
    let resp = engine.sddmm(h, &u, &v).unwrap();
    let entry = registry().canonical(SparseOp::Sddmm, resp.kernel);
    let est_s = workload::estimate(&entry.variant, rows, nnz, 8);
    assert_eq!(est_s.flops, 2 * nnz as u64 * 8, "SDDMM flop model is 2·nnz·d");
    let t = engine.metrics.workload_totals(entry.id).expect("sddmm workload recorded");
    assert_eq!((t.execs, t.flops), (1, est_s.flops));
    assert_eq!(t.bytes_written, est_s.bytes_written);
    assert_eq!(
        engine.metrics.workload_flops_total(),
        est.flops + est_s.flops,
        "the global flop counter sums both ops"
    );

    // unsharded requests never touch the shard-imbalance counters
    assert_eq!(engine.metrics.shard_imbalance_batches(), 0);
}

#[test]
fn sharded_requests_account_workload_per_shard_with_imbalance() {
    use ge_spmm::kernels::registry;

    let a = uniform_csr(512, 48, 0.08, 83);
    let (rows, nnz) = (a.rows, a.nnz());
    let engine = SpmmEngine::sharded(2);
    let h = engine.register(a).unwrap();
    let mut rng = Xoshiro256::seeded(84);
    let x = int_dense(48, 4, &mut rng);
    engine.spmm(h, &x).unwrap();

    // per-shard records partition the matrix exactly — and the request
    // grain did NOT also book the whole matrix (no double counting)
    let m = &engine.metrics;
    let (mut execs, mut wrows, mut wnnz, mut flops) = (0u64, 0u64, 0u64, 0u64);
    for e in registry().entries() {
        if let Some(t) = m.workload_totals(e.id) {
            assert_eq!(e.variant.op, SparseOp::Spmm);
            execs += t.execs;
            wrows += t.rows;
            wnnz += t.nnz;
            flops += t.flops;
        }
    }
    assert_eq!(execs, 2, "one workload record per shard, nothing else");
    assert_eq!(wrows, rows as u64, "shards partition the rows");
    assert_eq!(wnnz, nnz as u64, "shards partition the nnz");
    assert_eq!(flops, 2 * nnz as u64 * 4);

    // the fan-out recorded one imbalance batch; a milli-ratio of 1000
    // means perfectly nnz-balanced shards, and the partitioner balances
    // by nnz, so the ratio stays close to that floor
    assert_eq!(m.shard_imbalance_batches(), 1);
    assert!(m.shard_imbalance_mean_milli() >= 1000);
    assert!(m.shard_imbalance_max_milli() >= m.shard_imbalance_mean_milli());

    // the exposition carries the same totals
    let snap = expo::snapshot(m);
    let wl = snap.get("workload").unwrap();
    assert_eq!(wl.get("flops_total").unwrap().as_usize(), Some(flops as usize));
    let imb = wl.get("shard_imbalance").unwrap();
    assert_eq!(imb.get("batches").unwrap().as_usize(), Some(1));
}

#[test]
fn regret_converges_to_zero_under_a_forced_optimal_selector() {
    use ge_spmm::features::MatrixFeatures;
    use ge_spmm::kernels::registry;
    use ge_spmm::selector::{OnlineConfig, OnlineSelector};

    let metrics = Arc::new(Metrics::default());
    let online = OnlineSelector::new(
        AdaptiveSelector::default(),
        metrics.clone(),
        OnlineConfig {
            explore_every: 0,
            refit_every: 0,
            ..OnlineConfig::default()
        },
    );
    let a = uniform_csr(64, 48, 0.05, 91);
    let f = MatrixFeatures::of(&a);
    let entry = registry().canonical(SparseOp::Spmm, online.select(&f, 8));
    // constant latency: every realized cost equals the EWMA it updates,
    // so the chosen variant is always the best-known cell in its bucket
    for _ in 0..64 {
        online.observe_variant(&f, 8, entry, Duration::from_micros(40));
    }
    let report = online.regret_report();
    assert_eq!(report.folds, 64);
    assert_eq!(report.spmm_ratio, 0.0, "optimal selection folds zero regret");
    assert!(report.variants.is_empty(), "no mis-selected variants");

    // a consistently 10x-worse sibling: positive regret, attributed to it
    let worse = registry()
        .op_variants(SparseOp::Spmm)
        .iter()
        .find(|e| e.id != entry.id)
        .unwrap();
    for _ in 0..8 {
        online.observe_variant(&f, 8, worse, Duration::from_micros(400));
    }
    let report = online.regret_report();
    assert_eq!(report.folds, 72);
    assert!(report.spmm_ratio > 0.0, "mis-selection shows up in the ratio");
    assert_eq!(
        report.variants.first().map(|v| v.id),
        Some(worse.id),
        "the worst offender leads the mis-selected list"
    );
    // and the per-bucket table carries it too
    assert!(report.buckets.iter().any(|b| b.regret_ratio > 0.0));
    assert!(metrics.regret().report().render().contains("regret: folds=72"));
}

#[test]
fn chrome_trace_export_is_valid_and_well_nested() {
    use std::collections::HashMap;

    let (engine, hs, hl) = serving_pair();
    let mut rng = Xoshiro256::seeded(93);
    let x = int_dense(48, 4, &mut rng);
    engine.spmm(hs, &x).unwrap();
    engine.spmm(hl, &x).unwrap();
    let json = engine.metrics.recorder().chrome_trace_json();
    let reparsed = Json::parse(&json.to_string_pretty()).unwrap();
    assert_eq!(reparsed, json, "the export is valid, round-trippable JSON");
    assert_eq!(json.get("displayTimeUnit").and_then(|j| j.as_str()), Some("ms"));

    // per tid: B/E events obey stack discipline with matching names
    let events = json.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    let mut stacks: HashMap<usize, Vec<String>> = HashMap::new();
    for ev in events {
        let ph = ev.get("ph").unwrap().as_str().unwrap();
        if ph == "M" {
            continue; // thread-name metadata
        }
        let tid = ev.get("tid").unwrap().as_usize().unwrap();
        let name = ev.get("name").unwrap().as_str().unwrap().to_string();
        match ph {
            "B" => stacks.entry(tid).or_default().push(name),
            "E" => {
                let top = stacks.get_mut(&tid).and_then(|s| s.pop());
                assert_eq!(top.as_deref(), Some(name.as_str()), "E closes the open B");
            }
            other => panic!("unexpected phase {other}"),
        }
    }
    for (tid, stack) in stacks {
        assert!(stack.is_empty(), "tid {tid} left spans open: {stack:?}");
    }

    let other = json.get("otherData").unwrap();
    assert_eq!(other.get("committed").unwrap().as_usize(), Some(2));
    assert_eq!(other.get("dropped").unwrap().as_usize(), Some(0));
    let exemplars = other.get("exemplars").unwrap().as_arr().unwrap();
    assert!(!exemplars.is_empty(), "committed traces leave exemplars");
    for e in exemplars {
        assert!(e.get("trace_id").unwrap().as_usize().unwrap() >= 1);
        assert!(e.get("duration_ns").unwrap().as_f64().unwrap() > 0.0);
    }
}

#[test]
fn slo_monitor_flips_to_breaching_on_served_latency() {
    use ge_spmm::obs::{SloMonitor, SloSpec};

    let (engine, hs, hl) = serving_pair();
    // an impossible 1ns p99 target so real requests must breach it, and a
    // huge queue target that must not; a huge window so slice expiry
    // never races the test
    let mut spec = SloSpec::parse("p99=1ns,queue=1000000").unwrap();
    spec.window = Some(Duration::from_secs(3600));
    let monitor = Arc::new(SloMonitor::new(spec));
    engine.metrics.install_slo(monitor.clone());

    let server = Server::start(
        engine.clone(),
        ServerConfig {
            max_width: 1000,
            max_delay: Duration::from_millis(1),
            workers: 2,
            max_queue: 64,
        },
    );
    let mut rng = Xoshiro256::seeded(94);
    let mut replies = Vec::new();
    for (tag, h) in [(1u64, hs), (2u64, hl), (3u64, hs)] {
        let (rtx, rrx) = mpsc::channel();
        assert!(server.submit(Request::spmm(h, int_dense(48, 3, &mut rng), tag, rtx)));
        replies.push(rrx);
    }
    for rrx in replies {
        match rrx.recv_timeout(Duration::from_secs(60)).unwrap() {
            ServerReply::Ok(_) => {}
            ServerReply::Err(e) => panic!("served request failed: {e}"),
        }
    }
    server.shutdown();

    assert_eq!(monitor.observed(), 3, "every delivered reply is observed");
    let report = monitor.report();
    let p99 = report.objectives.iter().find(|o| o.name == "p99").unwrap();
    assert!(p99.breaching, "1ns target must be breached by real requests");
    assert!(p99.burn_rate > 1.0);
    let queue = report.objectives.iter().find(|o| o.name == "queue").unwrap();
    assert!(!queue.breaching, "queue depth stays far under the target");
    assert!(!report.healthy());
    assert!(report.health_line().contains("BREACHING"), "{}", report.health_line());

    // the breach surfaces through the snapshot and the Prometheus text
    let snap = expo::snapshot(&engine.metrics);
    let slo = snap.get("slo").unwrap();
    assert_eq!(slo.get("healthy").and_then(|j| j.as_bool()), Some(false));
    assert_eq!(slo.get("observed").unwrap().as_usize(), Some(3));
    let text = expo::prometheus_text(&engine.metrics);
    assert!(text.contains("ge_spmm_slo_breaching{objective=\"p99\"} 1"), "{text}");
    assert!(text.contains("ge_spmm_slo_breaching{objective=\"queue\"} 0"), "{text}");
    assert!(text.contains("ge_spmm_slo_observed_total 3"), "{text}");
}

#[test]
fn trace_capacity_is_configurable_and_drops_are_counted() {
    let engine = SpmmEngine::serving_with_selector_traced(
        16 << 20,
        usize::MAX,
        2,
        AdaptiveSelector::default(),
        4,
    );
    assert_eq!(engine.metrics.recorder().capacity(), 4);
    let h = engine.register(uniform_csr(48, 40, 0.1, 95)).unwrap();
    let mut rng = Xoshiro256::seeded(96);
    let x = int_dense(40, 3, &mut rng);
    for _ in 0..10 {
        engine.spmm(h, &x).unwrap();
    }
    let rec = engine.metrics.recorder();
    assert_eq!(rec.committed(), 10);
    assert_eq!(rec.len(), 4, "the ring keeps only the newest N");
    assert_eq!(rec.dropped(), 6, "evictions are counted");

    let snap = expo::snapshot(&engine.metrics);
    let traces = snap.get("traces").unwrap();
    assert_eq!(traces.get("capacity").unwrap().as_usize(), Some(4));
    assert_eq!(traces.get("dropped").unwrap().as_usize(), Some(6));
    assert!(!traces.get("exemplars").unwrap().as_arr().unwrap().is_empty());
    let text = expo::prometheus_text(&engine.metrics);
    assert!(text.contains("ge_spmm_traces_dropped_total 6"), "{text}");
}
