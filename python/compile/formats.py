"""Sparse-format converters mirroring ``rust/src/sparse``.

The Pallas kernels need static shapes, so sparse matrices are padded into
two layouts (identical to the Rust side, see ``sparse/ell.rs`` and
``sparse/segments.rs``):

- **ELL** for the row-split kernels: ``(rows_padded, width)`` value/column
  planes, zero-filled past each row's true length;
- **segments** for the workload-balanced kernels: the CSR non-zero stream
  cut into fixed-length segments, each element carrying its row index;
  padding repeats the last real row with value 0.
"""

from __future__ import annotations

import dataclasses

import numpy as np

WARP = 32  # lane count of a segment (mirrors kernels::WARP in Rust)


@dataclasses.dataclass
class Csr:
    """Minimal CSR container (no scipy dependency)."""

    rows: int
    cols: int
    indptr: np.ndarray  # (rows+1,) int32
    indices: np.ndarray  # (nnz,) int32
    data: np.ndarray  # (nnz,) float32

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @staticmethod
    def from_coo(rows: int, cols: int, r: np.ndarray, c: np.ndarray, v: np.ndarray) -> "Csr":
        """Build CSR from triplets (sorted, duplicates summed)."""
        order = np.lexsort((c, r))
        r, c, v = r[order], c[order], v[order]
        # sum duplicates
        if len(r) > 0:
            keep = np.ones(len(r), dtype=bool)
            same = (r[1:] == r[:-1]) & (c[1:] == c[:-1])
            # accumulate runs of duplicates
            if same.any():
                out_r, out_c, out_v = [], [], []
                i = 0
                while i < len(r):
                    j = i
                    acc = 0.0
                    while j < len(r) and r[j] == r[i] and c[j] == c[i]:
                        acc += float(v[j])
                        j += 1
                    out_r.append(r[i])
                    out_c.append(c[i])
                    out_v.append(acc)
                    i = j
                r = np.array(out_r, dtype=np.int64)
                c = np.array(out_c, dtype=np.int64)
                v = np.array(out_v, dtype=np.float64)
            del keep
        indptr = np.zeros(rows + 1, dtype=np.int32)
        np.add.at(indptr[1:], r.astype(np.int64), 1)
        indptr = np.cumsum(indptr, dtype=np.int32)
        return Csr(rows, cols, indptr, c.astype(np.int32), v.astype(np.float32))

    @staticmethod
    def random(rows: int, cols: int, density: float, rng: np.random.Generator) -> "Csr":
        mask = rng.random((rows, cols)) < density
        r, c = np.nonzero(mask)
        v = rng.normal(size=len(r)).astype(np.float32)
        return Csr.from_coo(rows, cols, r, c, v)

    def to_dense(self) -> np.ndarray:
        out = np.zeros((self.rows, self.cols), np.float32)
        for row in range(self.rows):
            lo, hi = self.indptr[row], self.indptr[row + 1]
            np.add.at(out[row], self.indices[lo:hi], self.data[lo:hi])
        return out

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.indptr)


@dataclasses.dataclass
class Ell:
    """Padded ELLPACK planes (mirrors ``EllMatrix``)."""

    rows: int
    cols: int
    rows_padded: int
    width: int
    values: np.ndarray  # (rows_padded, width) f32
    col_idx: np.ndarray  # (rows_padded, width) i32


def to_ell(csr: Csr, width_align: int = 8, row_block: int = 8, min_width: int | None = None) -> Ell:
    """Convert CSR → ELL with width/row padding (identical to the Rust
    converter). ``min_width`` forces at least that padded width so a matrix
    can target a fixed artifact bucket."""
    lens = csr.row_lengths()
    max_nnz = int(lens.max()) if csr.rows else 0
    width = max(-(-max_nnz // width_align), 1) * width_align
    if min_width is not None:
        if max_nnz > min_width:
            raise ValueError(f"row length {max_nnz} exceeds bucket width {min_width}")
        width = min_width
    rows_padded = -(-csr.rows // row_block) * row_block
    values = np.zeros((rows_padded, width), np.float32)
    col_idx = np.zeros((rows_padded, width), np.int32)
    for r in range(csr.rows):
        lo, hi = csr.indptr[r], csr.indptr[r + 1]
        values[r, : hi - lo] = csr.data[lo:hi]
        col_idx[r, : hi - lo] = csr.indices[lo:hi]
    return Ell(csr.rows, csr.cols, rows_padded, width, values, col_idx)


@dataclasses.dataclass
class Segments:
    """Fixed-nnz segment planes (mirrors ``SegmentedMatrix``)."""

    rows: int
    cols: int
    seg_len: int
    num_segments: int
    values: np.ndarray  # (num_segments, seg_len) f32
    col_idx: np.ndarray  # (num_segments, seg_len) i32
    row_idx: np.ndarray  # (num_segments, seg_len) i32
    nnz: int


def to_segments(csr: Csr, seg_len: int = WARP, min_segments: int | None = None) -> Segments:
    """Cut the CSR stream into fixed-length segments; padding repeats the
    last real (row, col) with value 0 so it folds into an existing run."""
    nnz = csr.nnz
    num_segments = max(-(-nnz // seg_len), 1)
    if min_segments is not None:
        if num_segments > min_segments:
            raise ValueError(f"{num_segments} segments exceed bucket {min_segments}")
        num_segments = min_segments
    padded = num_segments * seg_len
    rows = np.repeat(np.arange(csr.rows, dtype=np.int32), csr.row_lengths())
    vals = np.zeros(padded, np.float32)
    cols = np.zeros(padded, np.int32)
    ridx = np.zeros(padded, np.int32)
    vals[:nnz] = csr.data
    cols[:nnz] = csr.indices
    ridx[:nnz] = rows
    if nnz > 0:
        cols[nnz:] = cols[nnz - 1]
        ridx[nnz:] = ridx[nnz - 1]
    return Segments(
        csr.rows,
        csr.cols,
        seg_len,
        num_segments,
        vals.reshape(num_segments, seg_len),
        cols.reshape(num_segments, seg_len),
        ridx.reshape(num_segments, seg_len),
        nnz,
    )


def pad_rows(m: int, block: int) -> int:
    """Round a row count up to a block multiple."""
    return -(-m // block) * block
