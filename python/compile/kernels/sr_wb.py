"""SR-WB Pallas kernel — sequential reduction over fixed-nnz segments.

The workload-balancing half of the paper's design space (Fig. 2(b)):
every grid step owns a block of equal-size non-zero segments, so the work
per step is constant regardless of the row-length distribution. Because
segments cross row boundaries, the kernel carries an accumulator and
flushes it whenever the row index changes (read-modify-write into the full
output block — the TPU grid is sequential, so accumulation across grid
steps is well-defined; the CUDA version uses atomics here).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SEG_BLOCK = 128  # segments per grid step (§Perf: fewer interpreter grid steps)


def _kernel(vals_ref, cols_ref, rows_ref, x_ref, o_ref):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    sb, s = vals_ref.shape
    n = x_ref.shape[1]
    total = sb * s
    vals = vals_ref[...].reshape(total)
    cols = cols_ref[...].reshape(total)
    rows = rows_ref[...].reshape(total)
    x = x_ref[...]
    # CSR-Stream shape: the *loads* are parallel (one coalesced gather of
    # every fragment in the block — §Perf hoisted this out of the loop),
    # the *reduction* stays sequential per element.
    prod = jnp.take(x, cols, axis=0) * vals[:, None]

    def body(i, carry):
        acc, cur = carry
        r = rows[i]
        same = r == cur

        # flush the finished row run (sequential grid ⇒ safe accumulate)
        @pl.when(jnp.logical_not(same))
        def _flush():
            prev = o_ref[pl.ds(cur, 1), :]
            o_ref[pl.ds(cur, 1), :] = prev + acc[None, :]

        acc = jnp.where(same, acc, jnp.zeros_like(acc))
        return acc + prod[i], r

    init = (jnp.zeros((n,), jnp.float32), rows[0])
    acc, cur = jax.lax.fori_loop(0, total, body, init)
    # trailing run
    prev = o_ref[pl.ds(cur, 1), :]
    o_ref[pl.ds(cur, 1), :] = prev + acc[None, :]


@functools.partial(jax.jit, static_argnames=("m_pad", "seg_block"))
def spmm(
    values: jnp.ndarray,
    col_idx: jnp.ndarray,
    row_idx: jnp.ndarray,
    x: jnp.ndarray,
    *,
    m_pad: int,
    seg_block: int = SEG_BLOCK,
):
    """Y[m_pad, N] = segments(values, col_idx, row_idx) · X."""
    nseg, s = values.shape
    k, n = x.shape
    assert nseg % seg_block == 0, f"{nseg} segments not a multiple of {seg_block}"
    grid = (nseg // seg_block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((seg_block, s), lambda b: (b, 0)),
            pl.BlockSpec((seg_block, s), lambda b: (b, 0)),
            pl.BlockSpec((seg_block, s), lambda b: (b, 0)),
            pl.BlockSpec((k, n), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m_pad, n), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), jnp.float32),
        interpret=True,
    )(values, col_idx, row_idx, x)
