"""SR-RS Pallas kernel — sequential reduction, row split (+ CSC analog).

TPU adaptation of the paper's baseline/CSC design (see DESIGN.md
§Hardware-Adaptation): the grid walks row blocks; the padded ELL row
(``values``/``col_idx``) *is* the staged sparse tile — BlockSpec brings it
from HBM to VMEM in one contiguous transfer, and the dense fragments for
the whole block are gathered up front (the CSC insight: coalesced loads
first, then iterate out of fast memory). The reduction itself is an
explicit sequential ``fori_loop`` over the row width — sequential
reduction, exactly the paper's design axis.

Pallas runs ``interpret=True`` — correct numerics on the CPU PJRT backend;
real-TPU lowering would emit a Mosaic custom call this environment cannot
execute (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# rows per grid step (§Perf: fewer interpreter grid steps)
ROW_BLOCK = 128


def _kernel(vals_ref, cols_ref, x_ref, o_ref):
    vals = vals_ref[...]  # (RB, W)
    cols = cols_ref[...]
    x = x_ref[...]
    rb, w = vals.shape
    n = x.shape[1]
    # CSC stage-in: coalesced gather of every (1, N) fragment the block
    # needs (HBM→VMEM), before any arithmetic
    frags = jnp.take(x, cols.reshape(-1), axis=0).reshape(rb, w, n)
    prod = vals[:, :, None] * frags
    # sequential reduction over the staged row (the SR design axis)
    def body(k, acc):
        return acc + prod[:, k, :]

    o_ref[...] = jax.lax.fori_loop(0, w, body, jnp.zeros((rb, n), jnp.float32))


@functools.partial(jax.jit, static_argnames=("row_block",))
def spmm(values: jnp.ndarray, col_idx: jnp.ndarray, x: jnp.ndarray, *, row_block: int = ROW_BLOCK):
    """Y[m_pad, N] = ELL(values, col_idx) · X. ``m_pad`` must divide by
    ``row_block``."""
    m_pad, width = values.shape
    k, n = x.shape
    assert m_pad % row_block == 0, f"{m_pad} rows not a multiple of {row_block}"
    grid = (m_pad // row_block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, width), lambda b: (b, 0)),
            pl.BlockSpec((row_block, width), lambda b: (b, 0)),
            pl.BlockSpec((k, n), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, n), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), jnp.float32),
        interpret=True,
    )(values, col_idx, x)
