"""Pure-numpy/jnp oracles — the correctness ground truth for every Pallas
kernel (pytest compares kernel output against these)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..formats import Csr, Ell, Segments


def spmm_dense(csr: Csr, x: np.ndarray) -> np.ndarray:
    """Reference Y = A·X via the dense reconstruction. O(M·K·N): tests only."""
    return csr.to_dense() @ x


def spmm_ell(ell: Ell, x: np.ndarray) -> np.ndarray:
    """Oracle over the padded ELL planes (padded rows included, zero)."""
    gathered = x[ell.col_idx.reshape(-1)].reshape(ell.rows_padded, ell.width, -1)
    return (ell.values[:, :, None] * gathered).sum(axis=1)


def spmm_segments(seg: Segments, x: np.ndarray, m_pad: int) -> np.ndarray:
    """Oracle over the segment planes: scatter-add of value×x-row."""
    out = np.zeros((m_pad, x.shape[1]), np.float32)
    v = seg.values.reshape(-1)
    c = seg.col_idx.reshape(-1)
    r = seg.row_idx.reshape(-1)
    np.add.at(out, r, v[:, None] * x[c])
    return out


def spmm_ell_jnp(values: jnp.ndarray, col_idx: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """jnp oracle used inside L2 gradient checks (differentiable wrt x)."""
    gathered = x[col_idx.reshape(-1)].reshape(values.shape[0], values.shape[1], -1)
    return (values[:, :, None] * gathered).sum(axis=1)
