"""Layer-1 Pallas kernels: the four designs of the paper Fig. 2 space."""
