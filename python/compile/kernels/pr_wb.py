"""PR-WB Pallas kernel — the paper's VSR (vectorized segment reduction).

The §2.1.1 contribution: workload-balancing *and* parallel reduction at
once. Each 32-lane segment computes its products vectorized, then runs the
segmented-scan network — log₂(32) shifted, row-match-masked adds, the
Pallas rendering of the CUDA ``__shfl``-based prefix network in Fig. 2(e).
After the scan, the lane at each row-run *start* holds that run's total
and dumps it (the paper's "compare with neighbor, dump if boundary").

Dumps accumulate into the full output block; the sequential TPU grid makes
cross-segment accumulation well-defined (CUDA uses atomics).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SEG_BLOCK = 128  # segments per grid step (§Perf: fewer interpreter grid steps)


def _kernel(vals_ref, cols_ref, rows_ref, x_ref, o_ref):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    sb, s = vals_ref.shape
    n = x_ref.shape[1]
    x = x_ref[...]
    vals = vals_ref[...]  # (SB, S)
    cols = cols_ref[...]
    rows = rows_ref[...]

    # 1. lane products, VDL fragments: (SB, S, N)
    frags = jnp.take(x, cols.reshape(-1), axis=0).reshape(sb, s, n)
    prod = vals[:, :, None] * frags

    # 2. segmented suffix scan within each segment: lane l accumulates
    #    lane l+d iff both lanes belong to the same row (the paper's
    #    "add if the indices of two elements match")
    d = 1
    while d < s:
        shifted = jnp.concatenate([prod[:, d:, :], jnp.zeros((sb, d, n), jnp.float32)], axis=1)
        rshift = jnp.concatenate([rows[:, d:], jnp.full((sb, d), -1, rows.dtype)], axis=1)
        match = (rshift == rows)[:, :, None]
        prod = prod + jnp.where(match, shifted, 0.0)
        d *= 2

    # 3. dump at row-run starts (compare with left neighbor). All dumps
    #    of the block land in one masked scatter-add — the §Perf change
    #    that replaced a per-lane store loop (on TPU the dumps would be
    #    a VMEM-accumulated dynamic-update; the scatter preserves the
    #    dump rule bit-for-bit).
    prev = jnp.concatenate([jnp.full((sb, 1), -1, rows.dtype), rows[:, :-1]], axis=1)
    is_start = (prev != rows).reshape(-1)
    flat_rows = rows.reshape(-1)
    flat_prod = prod.reshape(sb * s, n) * is_start[:, None]
    o_ref[...] = o_ref[...] + jnp.zeros_like(o_ref).at[flat_rows].add(flat_prod)


@functools.partial(jax.jit, static_argnames=("m_pad", "seg_block"))
def spmm(
    values: jnp.ndarray,
    col_idx: jnp.ndarray,
    row_idx: jnp.ndarray,
    x: jnp.ndarray,
    *,
    m_pad: int,
    seg_block: int = SEG_BLOCK,
):
    """Y[m_pad, N] = segments(values, col_idx, row_idx) · X via VSR."""
    nseg, s = values.shape
    k, n = x.shape
    assert nseg % seg_block == 0, f"{nseg} segments not a multiple of {seg_block}"
    grid = (nseg // seg_block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((seg_block, s), lambda b: (b, 0)),
            pl.BlockSpec((seg_block, s), lambda b: (b, 0)),
            pl.BlockSpec((seg_block, s), lambda b: (b, 0)),
            pl.BlockSpec((k, n), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((m_pad, n), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), jnp.float32),
        interpret=True,
    )(values, col_idx, row_idx, x)
