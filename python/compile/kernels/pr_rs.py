"""PR-RS Pallas kernel — parallel reduction, row split, with VDL.

TPU adaptation of CSR-Vector (paper Fig. 2(c)): for each row the whole
padded ELL row is processed *vectorized* — the elementwise multiply runs
across the lane dimension of the VPU, and the merge tree is ``jnp.sum``
over the width axis (XLA lowers it to a log-depth reduction). Each lane's
dense load is the contiguous ``(1, N)`` fragment of X — the VDL
optimization (§2.1.2): for N ∈ {2, 4} that fragment rides in the same
32-byte sector a single f32 would occupy.

The whole row block is reduced in one shot:

    Y[block] = Σ_k  vals[:, k, None] · X[cols[:, k], :]

which is exactly "N-partial-sums per lane, merge-tree at the end"
expressed in array form.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 128


def _kernel(vals_ref, cols_ref, x_ref, o_ref):
    vals = vals_ref[...]  # (RB, W)
    cols = cols_ref[...]
    x = x_ref[...]  # (K, N)
    rb, w = vals.shape
    # VDL gather: every (row, lane) pulls its (1, N) fragment
    frags = jnp.take(x, cols.reshape(-1), axis=0).reshape(rb, w, -1)
    # lane multiply + merge tree (jnp.sum lowers to a log-depth reduce)
    o_ref[...] = jnp.sum(vals[:, :, None] * frags, axis=1)


@functools.partial(jax.jit, static_argnames=("row_block",))
def spmm(values: jnp.ndarray, col_idx: jnp.ndarray, x: jnp.ndarray, *, row_block: int = ROW_BLOCK):
    """Y[m_pad, N] = ELL(values, col_idx) · X via parallel reduction."""
    m_pad, width = values.shape
    k, n = x.shape
    assert m_pad % row_block == 0, f"{m_pad} rows not a multiple of {row_block}"
    grid = (m_pad // row_block,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((row_block, width), lambda b: (b, 0)),
            pl.BlockSpec((row_block, width), lambda b: (b, 0)),
            pl.BlockSpec((k, n), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, n), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, n), jnp.float32),
        interpret=True,
    )(values, col_idx, x)
