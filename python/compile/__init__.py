"""Build-time Python package: Pallas kernels (L1), JAX GCN model (L2) and
the AOT lowering path. Never imported at runtime - the Rust coordinator
loads the HLO text artifacts this package emits."""
