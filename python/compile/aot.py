"""AOT lowering: JAX/Pallas → HLO **text** artifacts + manifest.json.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the ``xla`` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Emits, under ``--out-dir`` (default ``../artifacts``):

- ``spmm_<variant>_<bucket>_n<N>.hlo.txt`` for each of the paper's four
  kernel designs × shape buckets × dense widths — the kernel library the
  Rust coordinator routes requests to;
- ``gcn_step.hlo.txt`` / ``gcn_fwd.hlo.txt`` — the L2 GCN train step and
  inference forward;
- ``manifest.json`` describing every artifact's inputs/outputs so the Rust
  runtime can validate shapes before execution.

Run once via ``make artifacts``; Python never runs on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import pr_rs, pr_wb, sr_rs, sr_wb

# ----------------------------------------------------------------- buckets

# Shape buckets for the SpMM artifact library. A request is routed to the
# smallest bucket it fits; operands are zero-padded to the bucket shape.
BUCKETS = {
    # name: (m_pad, k, ell_width, num_segments, seg_len)
    "s": dict(m_pad=512, k=512, width=32, nseg=512, seg_len=32),
    "m": dict(m_pad=4096, k=4096, width=64, nseg=4096, seg_len=32),
}
N_VALUES = [1, 4, 32, 128]
ROW_BLOCK = 128
SEG_BLOCK = 128

# GCN model dimensions (Cora-scale synthetic graph; multiples of ROW_BLOCK)
GCN = dict(nodes=2816, feats=64, hidden=32, classes=7, width=32, lr=0.05)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def describe(shape, dtype):
    return {"shape": list(shape), "dtype": dtype}


def lower_spmm(variant: str, bucket: str, n: int):
    """Lower one SpMM artifact; returns (hlo_text, manifest_entry)."""
    b = BUCKETS[bucket]
    m_pad, k, width, nseg, seg_len = b["m_pad"], b["k"], b["width"], b["nseg"], b["seg_len"]
    if variant in ("sr_rs", "pr_rs"):
        kern = {"sr_rs": sr_rs, "pr_rs": pr_rs}[variant]

        def fn(vals, cols, x):
            return (kern.spmm(vals, cols, x, row_block=ROW_BLOCK),)

        args = (
            spec((m_pad, width)),
            spec((m_pad, width), jnp.int32),
            spec((k, n)),
        )
        inputs = [
            {"name": "a_values", **describe((m_pad, width), "f32")},
            {"name": "a_col_idx", **describe((m_pad, width), "i32")},
            {"name": "x", **describe((k, n), "f32")},
        ]
    else:
        kern = {"sr_wb": sr_wb, "pr_wb": pr_wb}[variant]

        def fn(vals, cols, rows, x):
            return (kern.spmm(vals, cols, rows, x, m_pad=m_pad, seg_block=SEG_BLOCK),)

        args = (
            spec((nseg, seg_len)),
            spec((nseg, seg_len), jnp.int32),
            spec((nseg, seg_len), jnp.int32),
            spec((k, n)),
        )
        inputs = [
            {"name": "a_values", **describe((nseg, seg_len), "f32")},
            {"name": "a_col_idx", **describe((nseg, seg_len), "i32")},
            {"name": "a_row_idx", **describe((nseg, seg_len), "i32")},
            {"name": "x", **describe((k, n), "f32")},
        ]
    lowered = jax.jit(fn).lower(*args)
    entry = {
        "kind": "spmm",
        "variant": variant,
        "bucket": bucket,
        "n": n,
        "params": {k2: v for k2, v in b.items()},
        "row_block": ROW_BLOCK,
        "seg_block": SEG_BLOCK,
        "inputs": inputs,
        "outputs": [{"name": "y", **describe((m_pad, n), "f32")}],
    }
    return to_hlo_text(lowered), entry


def lower_gcn_step():
    g = GCN
    nodes, feats, hidden, classes, width = (
        g["nodes"],
        g["feats"],
        g["hidden"],
        g["classes"],
        g["width"],
    )

    def fn(w1, w2, a_vals, a_cols, x, y, mask):
        return model.train_step(w1, w2, a_vals, a_cols, x, y, mask, lr=g["lr"])

    args = (
        spec((feats, hidden)),
        spec((hidden, classes)),
        spec((nodes, width)),
        spec((nodes, width), jnp.int32),
        spec((nodes, feats)),
        spec((nodes, classes)),
        spec((nodes,)),
    )
    lowered = jax.jit(fn).lower(*args)
    entry = {
        "kind": "gcn_step",
        "params": dict(g),
        "inputs": [
            {"name": "w1", **describe((feats, hidden), "f32")},
            {"name": "w2", **describe((hidden, classes), "f32")},
            {"name": "a_values", **describe((nodes, width), "f32")},
            {"name": "a_col_idx", **describe((nodes, width), "i32")},
            {"name": "features", **describe((nodes, feats), "f32")},
            {"name": "labels_onehot", **describe((nodes, classes), "f32")},
            {"name": "mask", **describe((nodes,), "f32")},
        ],
        "outputs": [
            {"name": "w1_new", **describe((feats, hidden), "f32")},
            {"name": "w2_new", **describe((hidden, classes), "f32")},
            {"name": "loss", **describe((), "f32")},
        ],
    }
    return to_hlo_text(lowered), entry


def lower_gcn_fwd():
    g = GCN
    nodes, feats, hidden, classes, width = (
        g["nodes"],
        g["feats"],
        g["hidden"],
        g["classes"],
        g["width"],
    )

    def fn(w1, w2, a_vals, a_cols, x):
        return (model.forward((w1, w2), a_vals, a_cols, x),)

    args = (
        spec((feats, hidden)),
        spec((hidden, classes)),
        spec((nodes, width)),
        spec((nodes, width), jnp.int32),
        spec((nodes, feats)),
    )
    lowered = jax.jit(fn).lower(*args)
    entry = {
        "kind": "gcn_fwd",
        "params": dict(g),
        "inputs": [
            {"name": "w1", **describe((feats, hidden), "f32")},
            {"name": "w2", **describe((hidden, classes), "f32")},
            {"name": "a_values", **describe((nodes, width), "f32")},
            {"name": "a_col_idx", **describe((nodes, width), "i32")},
            {"name": "features", **describe((nodes, feats), "f32")},
        ],
        "outputs": [{"name": "logits", **describe((nodes, classes), "f32")}],
    }
    return to_hlo_text(lowered), entry


VARIANTS = ["sr_rs", "sr_wb", "pr_rs", "pr_wb"]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", default="s,m", help="comma-separated bucket names")
    ap.add_argument("--n-values", default=",".join(str(n) for n in N_VALUES))
    ap.add_argument("--skip-gcn", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}

    buckets = [b for b in args.buckets.split(",") if b]
    n_values = [int(n) for n in args.n_values.split(",") if n]

    for bucket in buckets:
        for variant in VARIANTS:
            for n in n_values:
                name = f"spmm_{variant}_{bucket}_n{n}"
                text, entry = lower_spmm(variant, bucket, n)
                path = f"{name}.hlo.txt"
                with open(os.path.join(args.out_dir, path), "w") as f:
                    f.write(text)
                entry["name"] = name
                entry["file"] = path
                manifest["artifacts"].append(entry)
                print(f"wrote {path} ({len(text)} chars)")

    if not args.skip_gcn:
        for name, (text, entry) in {
            "gcn_step": lower_gcn_step(),
            "gcn_fwd": lower_gcn_fwd(),
        }.items():
            path = f"{name}.hlo.txt"
            with open(os.path.join(args.out_dir, path), "w") as f:
                f.write(text)
            entry["name"] = name
            entry["file"] = path
            manifest["artifacts"].append(entry)
            print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
