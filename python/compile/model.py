"""Layer 2 — GCN forward/backward in JAX, calling the Layer-1 kernels.

The paper's headline application is GNN training ("our kernel is being
integrated into popular graph learning frameworks to accelerate GNN
training"). This module defines a 2-layer GCN whose neighbor aggregation
is the Layer-1 SpMM kernel:

    H₁ = relu( Â·X · W₁ )          logits = Â·H₁ · W₂

with Â the symmetric GCN-normalized adjacency in padded ELL form. ``spmm``
carries a ``custom_vjp``: the backward pass routes the adjoint through the
*same kernel* on Âᵀ — and since Â is symmetric, on Â itself — so both
training directions exercise the Pallas kernel (no fallback to generic
XLA scatter in the bwd).

Everything here is build-time only; ``aot.py`` lowers ``train_step`` /
``forward`` to HLO text for the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import pr_rs

# Row block shared with the kernel grid; model dims must be multiples.
ROW_BLOCK = 128


@jax.custom_vjp
def spmm(values, col_idx, x):
    """Â·X through the Layer-1 kernel (PR-RS with VDL fragments)."""
    return pr_rs.spmm(values, col_idx, x, row_block=ROW_BLOCK)


def _spmm_fwd(values, col_idx, x):
    return spmm(values, col_idx, x), (values, col_idx)


def _spmm_bwd(res, g):
    values, col_idx = res
    # Â is symmetric ⇒ Âᵀ·g = Â·g: same kernel, same operand planes.
    dx = pr_rs.spmm(values, col_idx, g, row_block=ROW_BLOCK)
    return (
        jnp.zeros_like(values),  # adjacency is constant
        np.zeros(col_idx.shape, dtype=jax.dtypes.float0),
        dx,
    )


spmm.defvjp(_spmm_fwd, _spmm_bwd)


def forward(params, a_vals, a_cols, feats):
    """2-layer GCN logits."""
    w1, w2 = params
    h = jax.nn.relu(spmm(a_vals, a_cols, feats) @ w1)
    return spmm(a_vals, a_cols, h) @ w2


def masked_cross_entropy(logits, labels_onehot, mask):
    """Softmax cross-entropy averaged over masked (labeled) nodes."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    per_node = -(labels_onehot * logp).sum(axis=-1)
    denom = jnp.maximum(mask.sum(), 1.0)
    return (per_node * mask).sum() / denom


def loss_fn(params, a_vals, a_cols, feats, labels_onehot, mask):
    return masked_cross_entropy(forward(params, a_vals, a_cols, feats), labels_onehot, mask)


def train_step(w1, w2, a_vals, a_cols, feats, labels_onehot, mask, lr=0.05):
    """One SGD step; returns (w1', w2', loss). This is the function the
    AOT path lowers — the Rust trainer feeds weights back in each step."""
    loss, grads = jax.value_and_grad(loss_fn)((w1, w2), a_vals, a_cols, feats, labels_onehot, mask)
    g1, g2 = grads
    return w1 - lr * g1, w2 - lr * g2, loss


def accuracy(logits, labels_onehot, mask):
    """Masked classification accuracy (used by tests and examples)."""
    pred = jnp.argmax(logits, axis=-1)
    true = jnp.argmax(labels_onehot, axis=-1)
    hits = (pred == true) * mask
    return hits.sum() / jnp.maximum(mask.sum(), 1.0)


def init_params(rng: np.random.Generator, n_feats: int, hidden: int, classes: int):
    """Glorot-ish initialization, float32."""
    s1 = np.sqrt(2.0 / (n_feats + hidden))
    s2 = np.sqrt(2.0 / (hidden + classes))
    w1 = (rng.normal(size=(n_feats, hidden)) * s1).astype(np.float32)
    w2 = (rng.normal(size=(hidden, classes)) * s2).astype(np.float32)
    return w1, w2


@functools.partial(jax.jit, static_argnames=("lr",))
def train_step_jit(w1, w2, a_vals, a_cols, feats, labels_onehot, mask, lr=0.05):
    return train_step(w1, w2, a_vals, a_cols, feats, labels_onehot, mask, lr=lr)
