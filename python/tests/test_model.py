"""L2 correctness: GCN forward/backward through the Pallas spmm.

Checks shapes, the custom-vjp gradient against a pure-jnp reference
implementation, and that a few SGD steps actually reduce the loss on a
learnable synthetic problem.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import formats, model
from compile.kernels import ref


def synthetic_graph(nodes=128, width=8, feats=16, classes=3, seed=0):
    """Small symmetric normalized graph in ELL form + learnable labels."""
    rng = np.random.default_rng(seed)
    # symmetric adjacency with self loops, degree capped at width-1
    adj = np.zeros((nodes, nodes), np.float32)
    for v in range(nodes):
        for u in rng.choice(nodes, size=rng.integers(1, (width - 1) // 2 + 1), replace=False):
            adj[v, u] = adj[u, v] = 1.0
    np.fill_diagonal(adj, 1.0)
    # clip degrees to the ELL width
    for v in range(nodes):
        nz = np.nonzero(adj[v])[0]
        if len(nz) > width:
            drop = nz[nz != v][: len(nz) - width]
            adj[v, drop] = adj[drop, v] = 0.0
    deg = adj.sum(1)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1e-9))
    norm = adj * dinv[:, None] * dinv[None, :]
    r, c = np.nonzero(norm)
    csr = formats.Csr.from_coo(nodes, nodes, r, c, norm[r, c])
    ell = formats.to_ell(csr, min_width=width, row_block=model.ROW_BLOCK)
    x = rng.normal(size=(nodes, feats)).astype(np.float32)
    # plant labels from a random GCN so the problem is learnable
    w1p, w2p = model.init_params(rng, feats, 8, classes)
    logits = ref.spmm_ell_jnp(ell.values, ell.col_idx, jnp.asarray(x))
    logits = jax.nn.relu(logits @ w1p)
    logits = ref.spmm_ell_jnp(ell.values, ell.col_idx, logits) @ w2p
    labels = np.asarray(jnp.argmax(logits, axis=-1))
    onehot = np.eye(classes, dtype=np.float32)[labels]
    mask = (rng.random(nodes) < 0.5).astype(np.float32)
    return ell, x, onehot, mask


@pytest.fixture(scope="module")
def problem():
    return synthetic_graph()


def test_forward_matches_jnp_reference(problem):
    ell, x, onehot, mask = problem
    rng = np.random.default_rng(1)
    params = model.init_params(rng, x.shape[1], 8, onehot.shape[1])
    got = model.forward(params, ell.values, ell.col_idx, x)

    def ref_forward(params, x):
        w1, w2 = params
        h = jax.nn.relu(ref.spmm_ell_jnp(ell.values, ell.col_idx, x) @ w1)
        return ref.spmm_ell_jnp(ell.values, ell.col_idx, h) @ w2

    want = ref_forward(params, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_custom_vjp_gradient_matches_reference(problem):
    ell, x, onehot, mask = problem
    rng = np.random.default_rng(2)
    params = model.init_params(rng, x.shape[1], 8, onehot.shape[1])

    def loss_kernel(params):
        return model.loss_fn(params, ell.values, ell.col_idx, x, onehot, mask)

    def loss_ref(params):
        w1, w2 = params
        h = jax.nn.relu(ref.spmm_ell_jnp(ell.values, ell.col_idx, jnp.asarray(x)) @ w1)
        logits = ref.spmm_ell_jnp(ell.values, ell.col_idx, h) @ w2
        return model.masked_cross_entropy(logits, onehot, mask)

    g_kernel = jax.grad(loss_kernel)(params)
    g_ref = jax.grad(loss_ref)(params)
    for gk, gr in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-3, atol=1e-4)


def test_training_reduces_loss(problem):
    ell, x, onehot, mask = problem
    rng = np.random.default_rng(3)
    w1, w2 = model.init_params(rng, x.shape[1], 8, onehot.shape[1])
    losses = []
    for _ in range(12):
        w1, w2, loss = model.train_step_jit(
            w1, w2, ell.values, ell.col_idx, x, onehot, mask, lr=0.5
        )
        losses.append(float(loss))
    assert losses[-1] < 0.7 * losses[0], f"loss did not drop: {losses}"


def test_accuracy_improves(problem):
    ell, x, onehot, mask = problem
    rng = np.random.default_rng(4)
    w1, w2 = model.init_params(rng, x.shape[1], 8, onehot.shape[1])
    logits0 = model.forward((w1, w2), ell.values, ell.col_idx, x)
    acc0 = float(model.accuracy(logits0, onehot, mask))
    for _ in range(25):
        w1, w2, _ = model.train_step_jit(
            w1, w2, ell.values, ell.col_idx, x, onehot, mask, lr=0.5
        )
    logits1 = model.forward((w1, w2), ell.values, ell.col_idx, x)
    acc1 = float(model.accuracy(logits1, onehot, mask))
    assert acc1 > acc0 + 0.1, f"accuracy {acc0} -> {acc1}"


def test_train_step_shapes_and_finiteness(problem):
    ell, x, onehot, mask = problem
    rng = np.random.default_rng(5)
    w1, w2 = model.init_params(rng, x.shape[1], 8, onehot.shape[1])
    n_w1, n_w2, loss = model.train_step_jit(
        w1, w2, ell.values, ell.col_idx, x, onehot, mask
    )
    assert n_w1.shape == w1.shape and n_w2.shape == w2.shape
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(n_w1)).all()
