"""L1 correctness: every Pallas kernel vs the pure-numpy oracle.

This is the core correctness signal for the compile path: if these pass,
the HLO artifacts the Rust runtime executes compute the right numbers.
Hypothesis sweeps shapes, densities and N; fixed seeds keep CI stable.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import formats
from compile.kernels import pr_rs, pr_wb, ref, sr_rs, sr_wb

RNG = np.random.default_rng(12345)


def make_problem(rows, cols, n, density, seed, max_row=None):
    rng = np.random.default_rng(seed)
    csr = formats.Csr.random(rows, cols, density, rng)
    if max_row is not None:
        assert csr.row_lengths().max() <= max_row
    x = rng.normal(size=(cols, n)).astype(np.float32)
    return csr, x


def run_ell_kernel(kernel, csr, x, row_block=8):
    ell = formats.to_ell(csr, width_align=4, row_block=row_block)
    out = np.asarray(kernel.spmm(ell.values, ell.col_idx, x, row_block=row_block))
    want = ref.spmm_ell(ell, x)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
    # padded rows are exact zeros
    np.testing.assert_array_equal(out[csr.rows :], 0.0)
    # and the real rows match the dense reference
    np.testing.assert_allclose(out[: csr.rows], ref.spmm_dense(csr, x), rtol=1e-4, atol=1e-4)


def run_seg_kernel(kernel, csr, x, seg_len=8, seg_block=4):
    seg = formats.to_segments(csr, seg_len=seg_len)
    # pad segments to the block multiple
    nseg = formats.pad_rows(seg.num_segments, seg_block)
    if nseg != seg.num_segments:
        pad = nseg - seg.num_segments
        seg.values = np.concatenate([seg.values, np.zeros((pad, seg_len), np.float32)])
        last_c = seg.col_idx[-1, -1]
        last_r = seg.row_idx[-1, -1]
        seg.col_idx = np.concatenate([seg.col_idx, np.full((pad, seg_len), last_c, np.int32)])
        seg.row_idx = np.concatenate([seg.row_idx, np.full((pad, seg_len), last_r, np.int32)])
        seg.num_segments = nseg
    m_pad = formats.pad_rows(csr.rows, 8)
    out = np.asarray(
        kernel.spmm(seg.values, seg.col_idx, seg.row_idx, x, m_pad=m_pad, seg_block=seg_block)
    )
    want = ref.spmm_segments(seg, x, m_pad)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out[: csr.rows], ref.spmm_dense(csr, x), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- fixed cases


@pytest.mark.parametrize("kernel", [sr_rs, pr_rs], ids=["sr_rs", "pr_rs"])
@pytest.mark.parametrize("n", [1, 2, 4, 16])
def test_ell_kernels_match_reference(kernel, n):
    csr, x = make_problem(37, 29, n, 0.2, seed=1)
    run_ell_kernel(kernel, csr, x)


@pytest.mark.parametrize("kernel", [sr_wb, pr_wb], ids=["sr_wb", "pr_wb"])
@pytest.mark.parametrize("n", [1, 2, 4, 16])
def test_segment_kernels_match_reference(kernel, n):
    csr, x = make_problem(37, 29, n, 0.2, seed=2)
    run_seg_kernel(kernel, csr, x)


@pytest.mark.parametrize("kernel", [sr_wb, pr_wb], ids=["sr_wb", "pr_wb"])
def test_segment_kernels_mega_row(kernel):
    """One row holding most non-zeros: runs span many segments/blocks."""
    rng = np.random.default_rng(3)
    r = np.concatenate([np.full(100, 3), np.arange(20)])
    c = np.concatenate([rng.permutation(128)[:100], rng.integers(0, 128, 20)])
    v = rng.normal(size=len(r)).astype(np.float32)
    csr = formats.Csr.from_coo(24, 128, r, c, v)
    x = rng.normal(size=(128, 4)).astype(np.float32)
    run_seg_kernel(kernel, csr, x)


@pytest.mark.parametrize("kernel", [sr_rs, pr_rs], ids=["sr_rs", "pr_rs"])
def test_ell_kernels_empty_rows(kernel):
    csr = formats.Csr.from_coo(
        16, 16, np.array([0, 15]), np.array([5, 2]), np.array([1.5, -2.0], np.float32)
    )
    x = RNG.normal(size=(16, 3)).astype(np.float32)
    run_ell_kernel(kernel, csr, x)


def test_all_four_kernels_agree():
    """The four designs must compute identical results on the same input."""
    csr, x = make_problem(50, 40, 8, 0.15, seed=4)
    ell = formats.to_ell(csr, width_align=4, row_block=8)
    a = np.asarray(sr_rs.spmm(ell.values, ell.col_idx, x, row_block=8))[: csr.rows]
    b = np.asarray(pr_rs.spmm(ell.values, ell.col_idx, x, row_block=8))[: csr.rows]
    seg = formats.to_segments(csr, seg_len=8)
    nseg = formats.pad_rows(seg.num_segments, 4)
    pad = nseg - seg.num_segments
    if pad:
        seg.values = np.concatenate([seg.values, np.zeros((pad, 8), np.float32)])
        seg.col_idx = np.concatenate([seg.col_idx, np.full((pad, 8), seg.col_idx[-1, -1], np.int32)])
        seg.row_idx = np.concatenate([seg.row_idx, np.full((pad, 8), seg.row_idx[-1, -1], np.int32)])
    m_pad = formats.pad_rows(csr.rows, 8)
    c = np.asarray(sr_wb.spmm(seg.values, seg.col_idx, seg.row_idx, x, m_pad=m_pad, seg_block=4))[: csr.rows]
    d = np.asarray(pr_wb.spmm(seg.values, seg.col_idx, seg.row_idx, x, m_pad=m_pad, seg_block=4))[: csr.rows]
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(a, d, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- hypothesis


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(4, 40),
    cols=st.integers(4, 40),
    n=st.sampled_from([1, 2, 3, 4, 8]),
    density=st.floats(0.05, 0.5),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_ell_kernels(rows, cols, n, density, seed):
    csr, x = make_problem(rows, cols, n, density, seed)
    run_ell_kernel(sr_rs, csr, x)
    run_ell_kernel(pr_rs, csr, x)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(4, 40),
    cols=st.integers(4, 40),
    n=st.sampled_from([1, 2, 4, 8]),
    density=st.floats(0.05, 0.5),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_segment_kernels(rows, cols, n, density, seed):
    csr, x = make_problem(rows, cols, n, density, seed)
    run_seg_kernel(sr_wb, csr, x)
    run_seg_kernel(pr_wb, csr, x)


# -------------------------------------------------------------- formats


def test_ell_roundtrip_matches_dense():
    csr, _ = make_problem(23, 31, 1, 0.3, seed=5)
    ell = formats.to_ell(csr, width_align=8, row_block=4)
    dense = np.zeros((csr.rows, csr.cols), np.float32)
    for r in range(csr.rows):
        for k in range(ell.width):
            dense[r, ell.col_idx[r, k]] += ell.values[r, k]
    np.testing.assert_allclose(dense, csr.to_dense(), rtol=1e-6, atol=1e-6)


def test_segments_cover_stream():
    csr, _ = make_problem(23, 31, 1, 0.3, seed=6)
    seg = formats.to_segments(csr, seg_len=8)
    flat_v = seg.values.reshape(-1)[: seg.nnz]
    np.testing.assert_array_equal(flat_v, csr.data)
    assert (seg.values.reshape(-1)[seg.nnz :] == 0).all()


def test_bucket_width_enforced():
    csr, _ = make_problem(8, 32, 1, 0.9, seed=7)
    with pytest.raises(ValueError):
        formats.to_ell(csr, min_width=2)


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 30), cols=st.integers(1, 30), density=st.floats(0.0, 0.6), seed=st.integers(0, 2**31))
def test_hypothesis_format_roundtrips(rows, cols, density, seed):
    rng = np.random.default_rng(seed)
    csr = formats.Csr.random(rows, cols, density, rng)
    ell = formats.to_ell(csr)
    np.testing.assert_allclose(
        ref.spmm_ell(ell, np.eye(cols, dtype=np.float32))[:rows], csr.to_dense(), rtol=1e-6, atol=1e-6
    )
    seg = formats.to_segments(csr)
    np.testing.assert_allclose(
        ref.spmm_segments(seg, np.eye(cols, dtype=np.float32), rows), csr.to_dense(), rtol=1e-6, atol=1e-6
    )
