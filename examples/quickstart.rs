//! Quickstart: the 60-second tour of the public API.
//!
//! Generates a sparse matrix, inspects its features, lets the adaptive
//! selector pick a kernel, executes the SpMM on the default native
//! backend, and cross-checks the numbers against the dense reference.
//! (Build an engine with `SpmmEngine::new(artifact_dir)` under the
//! `pjrt` feature to route the same calls to AOT artifacts instead.)
//!
//! These top-level examples are illustrative sources, not registered
//! Cargo example targets; `rust/tests/native_coordinator.rs` exercises
//! the same flow under `cargo test`.

use anyhow::Result;
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::features::MatrixFeatures;
use ge_spmm::gen::rmat::RmatConfig;
use ge_spmm::kernels::dense::spmm_reference;
use ge_spmm::sparse::{CsrMatrix, DenseMatrix};
use ge_spmm::util::prng::Xoshiro256;

fn main() -> Result<()> {
    // 1. A power-law sparse matrix (the paper's GNN/graph regime).
    let mut rng = Xoshiro256::seeded(42);
    let csr = CsrMatrix::from_coo(&RmatConfig::new(9, 6.0).generate(&mut rng));
    let feats = MatrixFeatures::of(&csr);
    println!("matrix:   {}", feats.summary());

    // 2. The coordinator: adaptive selector + native execution backend.
    let engine = SpmmEngine::native();
    let handle = engine.register(csr.clone())?;
    println!(
        "decision: {}",
        engine.selector.explain(&feats, 4)
    );

    // 3. Run Y = A·X through the coordinator.
    let x = DenseMatrix::random(csr.cols, 4, 1.0, &mut rng);
    let resp = engine.spmm(handle, &x)?;
    println!(
        "executed: kernel={} artifact={} latency={:?}",
        resp.kernel.label(),
        resp.artifact,
        resp.latency
    );

    // 4. Verify against the native CPU reference implementation.
    let mut want = DenseMatrix::zeros(csr.rows, 4);
    spmm_reference(&csr, &x, &mut want);
    let max_err = resp
        .y
        .data
        .iter()
        .zip(&want.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("checked:  max |err| vs native reference = {max_err:.2e}");
    assert!(max_err < 1e-4);

    // 5. Metrics the coordinator kept along the way.
    println!("metrics:  {}", engine.metrics.summary());
    Ok(())
}
