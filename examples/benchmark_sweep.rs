//! Figure-6-style sweep from the coordinator's perspective: for every
//! matrix in the benchmark collection and every dense width, compare
//! "ours" (oracle over the four designs) and "ours with rule-based"
//! against the cuSPARSE-like and ASpT-like baselines on all three GPU
//! models, printing per-family and overall geomean speedups.
//!
//!     cargo run --release --example benchmark_sweep [--full]

use ge_spmm::bench::figures::{
    geomean_speedup, load_bench_matrices, load_matrices, sim_ours_best, sim_ours_rules, sim_suite,
};
use ge_spmm::bench::Table;
use ge_spmm::gen::Collection;
use ge_spmm::selector::AdaptiveSelector;
use ge_spmm::sim::{GpuConfig, SimKernel};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    eprintln!("building collection …");
    let matrices = if full {
        load_matrices(Collection::suite())
    } else {
        load_bench_matrices()
    };
    eprintln!("{} matrices ready", matrices.len());
    let sel = AdaptiveSelector::default();

    for gpu in GpuConfig::all() {
        println!("\n=== {} ===", gpu.name);
        let mut t = Table::new(&[
            "N", "ours/cusparse", "rules/cusparse", "ours/aspt", "rules best-kernel share",
        ]);
        for n in [1usize, 4, 32, 128] {
            let cus = sim_suite(&matrices, SimKernel::CuSparse, n, &gpu);
            let aspt = sim_suite(&matrices, SimKernel::Aspt, n, &gpu);
            let best = sim_ours_best(&matrices, n, &gpu);
            let rules = sim_ours_rules(&matrices, &sel, n, &gpu);
            // fraction of matrices where the rules matched the oracle
            let mut hits = 0usize;
            for i in 0..matrices.len() {
                if rules[i] <= best[i] * 1.001 {
                    hits += 1;
                }
            }
            t.row(vec![
                n.to_string(),
                format!("{:.2}×", geomean_speedup(&cus, &best)),
                format!("{:.2}×", geomean_speedup(&cus, &rules)),
                format!("{:.2}×", geomean_speedup(&aspt, &best)),
                format!("{}/{}", hits, matrices.len()),
            ]);
        }
        t.print();
    }
}
