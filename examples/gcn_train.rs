//! End-to-end driver: GCN training on a Cora-scale synthetic graph
//! through the full three-layer stack — the Pallas SpMM kernel (L1)
//! inside the JAX train step (L2) executed by the Rust runtime (L3),
//! with Python nowhere on the path.
//!
//!     make artifacts && cargo run --release --example gcn_train
//!
//! Prints the loss curve; recorded runs (see BENCHMARKS.md for the
//! convention) used the default 300 steps.

use anyhow::Result;
use ge_spmm::gnn::{GcnTrainer, GraphConfig, SyntheticGraph};
use ge_spmm::runtime::Engine;
use std::path::Path;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let engine = Engine::new(Path::new("artifacts"))?;
    println!("platform: {}", engine.platform());

    let config = GraphConfig::default();
    println!(
        "graph: {} nodes (padded {}), {} feats, {} classes, ELL width {}",
        config.nodes, config.nodes_padded, config.feats, config.classes, config.width
    );
    let graph = SyntheticGraph::generate(config, 7);
    println!(
        "adjacency: nnz={} (gcn-normalized, symmetric)",
        graph.csr.nnz()
    );

    let mut trainer = GcnTrainer::new(&engine, &graph, 8)?;
    let t0 = std::time::Instant::now();
    let report = trainer.train(steps, 10)?;
    let per_step = t0.elapsed().as_secs_f64() / steps as f64;

    println!("\nloss curve (every 10 steps):");
    for (i, chunk) in report.losses.chunks(10).enumerate() {
        println!("  step {:4}  loss {:.4}", i * 10, chunk[0]);
    }
    println!(
        "\ntrained {} steps in {:.1}s ({:.0}ms/step)  final loss {:.4}  train-acc {:.3}",
        report.steps,
        report.seconds,
        per_step * 1e3,
        report.losses.last().unwrap(),
        report.train_accuracy
    );
    assert!(
        report.losses.last().unwrap() < &report.losses[0],
        "training must reduce the loss"
    );
    Ok(())
}
