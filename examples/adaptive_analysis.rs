//! Figure-5-style analysis: validate the three insights behind the
//! adaptive strategy on the benchmark collection.
//!
//!   left   — WB benefit at N=1 vs avg_row (short rows → WB wins)
//!   middle — PR vs SR speedup across N (PR wins only at small N)
//!   right  — WB benefit at N=128 vs stdv/avg (skew → WB wins)
//!
//!     cargo run --release --example adaptive_analysis

use ge_spmm::bench::figures::{load_bench_matrices, sim_suite, N_SWEEP};
use ge_spmm::bench::Table;
use ge_spmm::sim::{GpuConfig, SimKernel};
use ge_spmm::util::stats;

fn main() {
    let gpu = GpuConfig::rtx3090();
    eprintln!("building collection …");
    let matrices = load_bench_matrices();
    eprintln!("{} matrices ready on {}", matrices.len(), gpu.name);

    // ---- left panel: WB benefit (PR family) at N=1 vs avg_row ----
    println!("\n[Fig 5 left] workload-balancing benefit at N=1 vs avg_row");
    let pr_rs = sim_suite(&matrices, SimKernel::PrRs, 1, &gpu);
    let pr_wb = sim_suite(&matrices, SimKernel::PrWb, 1, &gpu);
    let benefit1: Vec<f64> = pr_rs.iter().zip(&pr_wb).map(|(a, b)| a / b).collect();
    let avg_rows: Vec<f64> = matrices.iter().map(|m| m.features.avg_row).collect();
    let mut t = Table::new(&["avg_row bucket", "matrices", "geomean WB benefit"]);
    for (lo, hi) in [(0.0, 4.0), (4.0, 12.0), (12.0, 40.0), (40.0, 1e9)] {
        let sel: Vec<f64> = (0..matrices.len())
            .filter(|&i| avg_rows[i] >= lo && avg_rows[i] < hi)
            .map(|i| benefit1[i])
            .collect();
        if !sel.is_empty() {
            t.row(vec![
                if hi > 1e8 { format!("≥{lo}") } else { format!("{lo}–{hi}") },
                sel.len().to_string(),
                format!("{:.2}×", stats::geomean(&sel)),
            ]);
        }
    }
    t.print();
    println!(
        "spearman(avg_row, WB benefit) = {:.2}  (paper: negative — short rows benefit)",
        stats::spearman(&avg_rows, &benefit1)
    );

    // ---- middle panel: PR vs SR across N ----
    println!("\n[Fig 5 middle] parallel- vs sequential-reduction across N");
    let mut t = Table::new(&["N", "geomean SR/PR (>1 ⇒ PR wins)"]);
    for n in N_SWEEP {
        let sr = sim_suite(&matrices, SimKernel::SrRs, n, &gpu);
        let pr = sim_suite(&matrices, SimKernel::PrRs, n, &gpu);
        let ratios: Vec<f64> = sr.iter().zip(&pr).map(|(s, p)| s / p).collect();
        t.row(vec![n.to_string(), format!("{:.2}×", stats::geomean(&ratios))]);
    }
    t.print();

    // ---- right panel: WB benefit (SR family) at N=128 vs cv ----
    println!("\n[Fig 5 right] workload-balancing benefit at N=128 vs stdv/avg");
    let sr_rs = sim_suite(&matrices, SimKernel::SrRs, 128, &gpu);
    let sr_wb = sim_suite(&matrices, SimKernel::SrWb, 128, &gpu);
    let benefit128: Vec<f64> = sr_rs.iter().zip(&sr_wb).map(|(a, b)| a / b).collect();
    let cvs: Vec<f64> = matrices.iter().map(|m| m.features.cv_row).collect();
    let mut t = Table::new(&["stdv/avg bucket", "matrices", "geomean WB benefit"]);
    for (lo, hi) in [(0.0, 0.25), (0.25, 1.0), (1.0, 3.0), (3.0, 1e9)] {
        let sel: Vec<f64> = (0..matrices.len())
            .filter(|&i| cvs[i] >= lo && cvs[i] < hi)
            .map(|i| benefit128[i])
            .collect();
        if !sel.is_empty() {
            t.row(vec![
                if hi > 1e8 { format!("≥{lo}") } else { format!("{lo}–{hi}") },
                sel.len().to_string(),
                format!("{:.2}×", stats::geomean(&sel)),
            ]);
        }
    }
    t.print();
    println!(
        "spearman(stdv/avg, WB benefit) = {:.2}  (paper: positive — skew benefits)",
        stats::spearman(&cvs, &benefit128)
    );
}
