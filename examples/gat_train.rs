//! End-to-end driver for the fused attention path: GAT-style dot-product
//! attention on a Cora-scale synthetic graph, running the fused
//! SDDMM→softmax→SpMM dataflow through the serving-shaped `SpmmEngine`
//! (prepared-matrix cache + size routing + per-shard adaptive selection)
//! on the default native build — no artifacts, no libxla.
//!
//! A linear classifier head is trained on top of the (frozen) attention
//! features; every epoch re-runs the fused attention forward through the
//! engine, so the loss curve exercises both sparse ops end to end.
//!
//! These top-level examples are illustrative sources, not registered
//! Cargo example targets; `rust/tests/sddmm_agreement.rs` and the
//! `gnn::attention` / `gnn::native_trainer` unit tests exercise the
//! same flow under `cargo test`.

use anyhow::Result;
use ge_spmm::coordinator::SpmmEngine;
use ge_spmm::gnn::{AttentionLayer, GraphConfig, SyntheticGraph};
use ge_spmm::sparse::DenseMatrix;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100)
        .max(1);

    let config = GraphConfig::default();
    let graph = SyntheticGraph::generate(config, 7);
    let n = config.nodes;
    let (feats, classes, head_dim) = (config.feats, config.classes, 16);
    println!(
        "graph: {} nodes, {} feats, {} classes, nnz={}",
        n,
        feats,
        classes,
        graph.csr.nnz()
    );

    // Serving-shaped engine: cached, size-routed, per-shard adaptive.
    let engine = SpmmEngine::serving(64 << 20, 4096, 2);
    // Unit-valued pattern: pure dot-product attention (the stored Â
    // weights would otherwise act as multiplicative edge priors).
    let pattern = graph.csr.with_values(vec![1.0; graph.csr.nnz()]);
    let h_adj = engine.register(pattern.clone())?;
    let x = DenseMatrix::from_vec(n, feats, graph.features[..n * feats].to_vec());
    let layer = AttentionLayer::new(feats, head_dim, 8);

    // Attention features are recomputed through the engine every epoch
    // (frozen projections), then a linear head trains on them.
    let mut w = vec![0f32; head_dim * classes];
    let lr = 0.5f32;
    let m: f32 = graph.mask[..n].iter().sum::<f32>().max(1.0);
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let fwd = layer.forward(&engine, &pattern, h_adj, &x)?;
        let feats_out = fwd.y; // n × head_dim
        let mut loss = 0.0f32;
        let mut dw = vec![0f32; head_dim * classes];
        for v in 0..n {
            if graph.mask[v] == 0.0 {
                continue;
            }
            let row = feats_out.row(v);
            let mut logits = vec![0f32; classes];
            for (j, l) in logits.iter_mut().enumerate() {
                for k in 0..head_dim {
                    *l += row[k] * w[k * classes + j];
                }
            }
            let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = logits.iter().map(|l| (l - mx).exp()).collect();
            let sum: f32 = exps.iter().sum();
            let label = graph.labels[v];
            loss -= (exps[label] / sum).max(1e-12).ln() / m;
            for j in 0..classes {
                let g = (exps[j] / sum - if j == label { 1.0 } else { 0.0 }) / m;
                for k in 0..head_dim {
                    dw[k * classes + j] += row[k] * g;
                }
            }
        }
        for (wi, gi) in w.iter_mut().zip(&dw) {
            *wi -= lr * gi;
        }
        losses.push(loss);
        if step % 10 == 0 || step + 1 == steps {
            println!(
                "step {step:4}  loss {loss:.4}  sddmm_kernel={} spmm_kernel={}",
                fwd.scores_kernel.label(),
                fwd.agg_kernel.label()
            );
        }
    }

    println!("\n{}", engine.metrics.summary());
    if let Some((entries, bytes)) = engine.cache_usage() {
        println!("cache: {entries} prepared matrices resident, {bytes} bytes");
    }
    assert!(
        losses.last().unwrap() < &losses[0],
        "training must reduce the loss"
    );
    Ok(())
}
